//! Piecewise-polynomial performance models and the per-setup model store
//! (paper §3.2.1, Fig. 3.9).
//!
//! A [`PerfModel`] covers one *case* — kernel + data type + flag/scalar/
//! increment combination — over a hyper-rectangular size domain tiled by
//! [`Piece`]s. Each piece carries one coefficient vector per summary
//! statistic (min/med/max/mean/std). A [`ModelStore`] holds all models of
//! one hardware/software setup and serializes to JSON.

use std::collections::HashMap;

use crate::machine::kernels::{Call, Scalar};
use crate::util::json::Json;
use crate::util::error::Result;
use crate::util::stats::{Stat, Summary};

use super::fit::eval_poly;
use super::grid::Domain;

/// One polynomial piece over a sub-domain.
#[derive(Clone, Debug, PartialEq)]
pub struct Piece {
    pub domain: Domain,
    /// Coefficients per statistic, indexed by `Stat::ALL` order.
    pub coeffs: [Vec<f64>; 5],
}

/// A piecewise multivariate polynomial runtime model for one case.
#[derive(Clone, Debug, Default)]
pub struct PerfModel {
    pub case: String,
    /// Monomial exponent table (M x dims).
    pub exps: Vec<Vec<u8>>,
    /// Per-dimension scaling divisor applied before monomial evaluation.
    pub scale: Vec<f64>,
    pub pieces: Vec<Piece>,
    /// Virtual seconds of measurements spent generating this model (the
    /// paper's "model cost", §3.3.2).
    pub gen_cost: f64,
    /// Lazily cached domain hull (§Perf: estimate() is the prediction hot
    /// path and must not rescan pieces per call).
    pub hull_cache: std::sync::OnceLock<Domain>,
}

impl PartialEq for PerfModel {
    fn eq(&self, other: &Self) -> bool {
        self.case == other.case
            && self.exps == other.exps
            && self.scale == other.scale
            && self.pieces == other.pieces
            && self.gen_cost == other.gen_cost
    }
}

impl PerfModel {
    pub fn dims(&self) -> usize {
        self.scale.len()
    }

    /// Bounding box of all pieces (computed once, cached).
    pub fn domain_hull(&self) -> &Domain {
        self.hull_cache.get_or_init(|| {
            let d = self.dims();
            let mut lo = vec![usize::MAX; d];
            let mut hi = vec![0usize; d];
            for p in &self.pieces {
                for i in 0..d {
                    lo[i] = lo[i].min(p.domain.lo[i]);
                    hi[i] = hi[i].max(p.domain.hi[i]);
                }
            }
            Domain::new(lo, hi)
        })
    }

    /// Index of the piece containing `sizes` (clamped into the hull).
    pub fn piece_index(&self, sizes: &[usize]) -> usize {
        let hull = self.domain_hull();
        let clamped: Vec<usize> = sizes
            .iter()
            .enumerate()
            .map(|(i, &v)| v.clamp(hull.lo[i], hull.hi[i]))
            .collect();
        // Boundary points belong to both neighbours; first match wins.
        self.pieces
            .iter()
            .position(|p| p.domain.contains(&clamped))
            .unwrap_or(0)
    }

    /// Scaled coordinates of a size point.
    pub fn scaled(&self, sizes: &[usize]) -> Vec<f64> {
        sizes
            .iter()
            .zip(&self.scale)
            .map(|(&v, &s)| v as f64 / s)
            .collect()
    }

    /// Runtime estimate (seconds) for a size point: all five statistics.
    ///
    /// Hot path of every prediction sweep (§Perf): clamping, piece lookup
    /// and monomial evaluation run in a single pass with no allocation
    /// beyond the scaled point.
    pub fn estimate(&self, sizes: &[usize]) -> Summary {
        // Zero-size operations execute no kernel body (Table 4.1).
        if sizes.iter().any(|&v| v == 0) {
            return Summary::constant(0.0);
        }
        let d = self.dims();
        let hull = self.domain_hull();
        let mut clamped = [0usize; 4];
        debug_assert!(d <= 4);
        for i in 0..d {
            clamped[i] = sizes[i].clamp(hull.lo[i], hull.hi[i]);
        }
        let clamped = &clamped[..d];
        let piece = self
            .pieces
            .iter()
            .position(|p| p.domain.contains(clamped))
            .unwrap_or(0);
        self.eval_in_piece(piece, clamped)
    }

    /// Evaluate all five statistic polynomials of one piece at an
    /// already-clamped point.
    fn eval_in_piece(&self, piece: usize, clamped: &[usize]) -> Summary {
        let coeffs = &self.pieces[piece].coeffs;
        let x = self.scaled(clamped);
        let mut out = Summary::constant(0.0);
        for (si, stat) in Stat::ALL.iter().enumerate() {
            let v = eval_poly(&self.exps, &coeffs[si], &x);
            // Polynomials can dip negative at domain edges; runtimes can't.
            out.set(*stat, v.max(if *stat == Stat::Std { 0.0 } else { 1e-12 }));
        }
        out
    }

    /// Batched estimates for a sweep of size points.
    ///
    /// Cache-aware piece lookup (§Perf): sweeps walk domains in order, so
    /// consecutive points usually land in the same piece — each point is
    /// first checked against the previously matched piece before falling
    /// back to the linear scan. Results are identical to calling
    /// [`PerfModel::estimate`] per point.
    pub fn evaluate_batch(&self, points: &[Vec<usize>]) -> Vec<Summary> {
        let d = self.dims();
        let hull = self.domain_hull();
        let mut out = Vec::with_capacity(points.len());
        let mut last: Option<usize> = None;
        for sizes in points {
            if sizes.iter().any(|&v| v == 0) {
                out.push(Summary::constant(0.0));
                continue;
            }
            let mut clamped = [0usize; 4];
            debug_assert!(d <= 4);
            for i in 0..d {
                clamped[i] = sizes[i].clamp(hull.lo[i], hull.hi[i]);
            }
            let clamped = &clamped[..d];
            // The shortcut applies only strictly inside the last piece:
            // there the containing piece is unique, so reusing it cannot
            // disagree with estimate()'s first-match rule on boundary
            // points shared by two neighbours.
            let piece = match last {
                Some(p) if strictly_inside(&self.pieces[p].domain, clamped) => p,
                _ => self
                    .pieces
                    .iter()
                    .position(|p| p.domain.contains(clamped))
                    .unwrap_or(0),
            };
            last = Some(piece);
            out.push(self.eval_in_piece(piece, clamped));
        }
        out
    }

    // ------------------------------------------------------------- JSON
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("case", Json::Str(self.case.clone())),
            (
                "exps",
                Json::Arr(
                    self.exps
                        .iter()
                        .map(|e| Json::arr_usize(&e.iter().map(|&v| v as usize).collect::<Vec<_>>()))
                        .collect(),
                ),
            ),
            ("scale", Json::arr_f64(&self.scale)),
            ("gen_cost", Json::Num(self.gen_cost)),
            (
                "pieces",
                Json::Arr(
                    self.pieces
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("lo", Json::arr_usize(&p.domain.lo)),
                                ("hi", Json::arr_usize(&p.domain.hi)),
                                (
                                    "coeffs",
                                    Json::Arr(
                                        p.coeffs.iter().map(|c| Json::arr_f64(c)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<PerfModel> {
        let arr_usize = |j: &Json| -> Result<Vec<usize>> {
            Ok(j.as_arr()
                .ok_or_else(|| crate::err!("expected array"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect())
        };
        let arr_f64 = |j: &Json| -> Result<Vec<f64>> {
            Ok(j.as_arr()
                .ok_or_else(|| crate::err!("expected array"))?
                .iter()
                .filter_map(|v| v.as_f64())
                .collect())
        };
        let exps = j
            .req("exps")?
            .as_arr()
            .ok_or_else(|| crate::err!("'exps' must be an array"))?
            .iter()
            .map(|e| Ok(arr_usize(e)?.into_iter().map(|v| v as u8).collect()))
            .collect::<Result<Vec<Vec<u8>>>>()?;
        let mut pieces = Vec::new();
        for pj in j
            .req("pieces")?
            .as_arr()
            .ok_or_else(|| crate::err!("'pieces' must be an array"))?
        {
            let lo = arr_usize(pj.req("lo")?)?;
            let hi = arr_usize(pj.req("hi")?)?;
            // Validate before Domain::new, whose assertions would panic.
            crate::ensure!(
                lo.len() == hi.len() && lo.iter().zip(&hi).all(|(l, h)| l <= h),
                "invalid piece domain: lo {lo:?} hi {hi:?}"
            );
            let cj = pj
                .req("coeffs")?
                .as_arr()
                .ok_or_else(|| crate::err!("'coeffs' must be an array"))?;
            crate::ensure!(cj.len() == 5, "expected 5 stat coefficient sets");
            let coeffs = [
                arr_f64(&cj[0])?,
                arr_f64(&cj[1])?,
                arr_f64(&cj[2])?,
                arr_f64(&cj[3])?,
                arr_f64(&cj[4])?,
            ];
            pieces.push(Piece { domain: Domain::new(lo, hi), coeffs });
        }
        Ok(PerfModel {
            case: j.req("case")?.as_str().unwrap_or("").to_string(),
            exps,
            scale: arr_f64(j.req("scale")?)?,
            pieces,
            gen_cost: j.req("gen_cost")?.as_f64().unwrap_or(0.0),
            hull_cache: std::sync::OnceLock::new(),
        })
    }
}

/// Strict interior test for the batched piece-lookup shortcut: a point
/// strictly inside a piece is contained by that piece alone.
fn strictly_inside(d: &Domain, x: &[usize]) -> bool {
    x.iter()
        .zip(d.lo.iter().zip(&d.hi))
        .all(|(&v, (&l, &h))| v > l && v < h)
}

/// Case key of a call: kernel + type prefix + flags + scalar class +
/// increment class (paper §3.2.1's "discrete cases").
pub fn case_key(call: &Call) -> String {
    let flags = call.flags.code();
    let alpha = match call.alpha {
        Scalar::MinusOne => "m1",
        Scalar::Zero => "0",
        Scalar::One => "1",
        Scalar::Other => "x",
    };
    let inc = if call.incx.max(call.incy) > 1 { "_iL" } else { "" };
    let flags = if flags.is_empty() { String::new() } else { format!("_{flags}") };
    format!(
        "{}{}{}_a{}{}",
        call.elem.prefix(),
        crate::machine::kernels::name(call.kernel),
        flags,
        alpha,
        inc
    )
}

/// All models of one hardware/software setup.
#[derive(Clone, Debug, Default)]
pub struct ModelStore {
    pub machine_label: String,
    pub models: HashMap<String, PerfModel>,
}

impl ModelStore {
    pub fn new(machine_label: &str) -> ModelStore {
        ModelStore { machine_label: machine_label.to_string(), models: HashMap::new() }
    }

    pub fn insert(&mut self, model: PerfModel) {
        self.models.insert(model.case.clone(), model);
    }

    pub fn get(&self, case: &str) -> Option<&PerfModel> {
        self.models.get(case)
    }

    /// Estimate a call's runtime summary; `None` if no model covers its
    /// case.
    pub fn estimate_call(&self, call: &Call) -> Option<Summary> {
        if call.sizes().iter().any(|&v| v == 0) {
            return Some(Summary::constant(0.0));
        }
        self.models.get(&case_key(call)).map(|m| m.estimate(&call.sizes()))
    }

    /// Total virtual measurement cost of all models. Summed in sorted
    /// order: f64 addition is order-dependent, and the map's iteration
    /// order is not, so an unsorted sum would drift across processes.
    pub fn total_gen_cost(&self) -> f64 {
        let mut costs: Vec<f64> = self.models.values().map(|m| m.gen_cost).collect();
        costs.sort_by(|a, b| a.total_cmp(b));
        costs.iter().sum()
    }

    pub fn to_json(&self) -> Json {
        let mut sorted: Vec<&PerfModel> = self.models.values().collect();
        sorted.sort_by(|a, b| a.case.cmp(&b.case));
        Json::obj(vec![
            ("machine", Json::Str(self.machine_label.clone())),
            ("models", Json::Arr(sorted.iter().map(|m| m.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ModelStore> {
        let mut store = ModelStore::new(j.req("machine")?.as_str().unwrap_or(""));
        for mj in j
            .req("models")?
            .as_arr()
            .ok_or_else(|| crate::err!("'models' must be an array"))?
        {
            store.insert(PerfModel::from_json(mj)?);
        }
        Ok(store)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().render())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<ModelStore> {
        let text = std::fs::read_to_string(path)?;
        ModelStore::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::kernels::{Diag, Flags, KernelId, Side, Trans, Uplo};
    use crate::machine::Elem;

    fn linear_model() -> PerfModel {
        // Two 1-D pieces: y = 1 + x on [8, 248], y = 2x on [248, 504].
        PerfModel {
            case: "dpotf2_L_a1".into(),
            exps: vec![vec![0], vec![1]],
            scale: vec![504.0],
            pieces: vec![
                Piece {
                    domain: Domain::new(vec![8], vec![248]),
                    coeffs: [
                        vec![1.0, 1.0],
                        vec![1.0, 1.0],
                        vec![1.0, 1.0],
                        vec![1.0, 1.0],
                        vec![0.0, 0.0],
                    ],
                },
                Piece {
                    domain: Domain::new(vec![248], vec![504]),
                    coeffs: [
                        vec![0.0, 2.0],
                        vec![0.0, 2.0],
                        vec![0.0, 2.0],
                        vec![0.0, 2.0],
                        vec![0.0, 0.0],
                    ],
                },
            ],
            gen_cost: 1.5,
            ..Default::default()
        }
    }

    #[test]
    fn estimate_picks_correct_piece() {
        let m = linear_model();
        let lo = m.estimate(&[104]); // x = 104/504
        assert!((lo.med - (1.0 + 104.0 / 504.0)).abs() < 1e-12);
        let hi = m.estimate(&[504]);
        assert!((hi.med - 2.0).abs() < 1e-12);
    }

    #[test]
    fn estimate_clamps_outside_domain() {
        let m = linear_model();
        let big = m.estimate(&[100_000]);
        assert!((big.med - 2.0).abs() < 1e-12); // clamped to hi = 504
        let small = m.estimate(&[1]);
        assert!((small.med - (1.0 + 8.0 / 504.0)).abs() < 1e-12);
    }

    #[test]
    fn zero_size_estimates_zero() {
        let m = linear_model();
        assert_eq!(m.estimate(&[0]).med, 0.0);
    }

    #[test]
    fn evaluate_batch_matches_per_point_estimates() {
        let m = linear_model();
        // Sweep crossing both pieces, out-of-domain points, a zero, and a
        // shared-boundary point (248) revisited right after a higher
        // piece matched — the first-match rule must still win there.
        let points: Vec<Vec<usize>> = [1usize, 8, 104, 248, 250, 400, 248, 504, 0, 100_000, 16]
            .iter()
            .map(|&v| vec![v])
            .collect();
        let batch = m.evaluate_batch(&points);
        assert_eq!(batch.len(), points.len());
        for (p, got) in points.iter().zip(&batch) {
            let want = m.estimate(p);
            assert_eq!(*got, want, "point {p:?}");
        }
    }

    #[test]
    fn json_roundtrip() {
        let m = linear_model();
        let j = m.to_json();
        let back = PerfModel::from_json(&j).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn store_roundtrip_via_file() {
        let mut store = ModelStore::new("haswell/openblas/1t");
        store.insert(linear_model());
        // Process- and call-unique dir so parallel/repeated runs cannot
        // collide (no wall clock involved; see util::sync::unique_token).
        let dir = std::env::temp_dir()
            .join(format!("dlapm_test_store_{}", crate::util::sync::unique_token()));
        let path = dir.join("models.json");
        // Cleanup runs on every exit path, including assertion unwinds.
        struct Cleanup(std::path::PathBuf);
        impl Drop for Cleanup {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
        let _cleanup = Cleanup(dir);
        store.save(&path).unwrap();
        let loaded = ModelStore::load(&path).unwrap();
        assert_eq!(loaded.machine_label, store.machine_label);
        assert_eq!(loaded.models.len(), 1);
        assert_eq!(loaded.get("dpotf2_L_a1").unwrap(), store.get("dpotf2_L_a1").unwrap());
    }

    #[test]
    fn case_key_encodes_flags_and_alpha() {
        let mut c = Call::new(KernelId::Trsm, Elem::D);
        c.flags = Flags {
            side: Some(Side::Right),
            uplo: Some(Uplo::Lower),
            trans_a: Some(Trans::Yes),
            diag: Some(Diag::NonUnit),
            trans_b: None,
        };
        c.alpha = Scalar::MinusOne;
        assert_eq!(case_key(&c), "dtrsm_RLTN_am1");
        c.alpha = Scalar::One;
        c.incx = 5000;
        assert_eq!(case_key(&c), "dtrsm_RLTN_a1_iL");
    }

    #[test]
    fn estimate_call_uses_case_key() {
        let mut store = ModelStore::new("x");
        store.insert(PerfModel { case: "dpotf2_L_a1".into(), ..linear_model() });
        let mut call = Call::new(KernelId::Potf2, Elem::D);
        call.flags.uplo = Some(Uplo::Lower);
        call.n = 104;
        let est = store.estimate_call(&call).unwrap();
        assert!(est.med > 1.0);
        call.flags.uplo = Some(Uplo::Upper); // no model for this case
        assert!(store.estimate_call(&call).is_none());
    }
}
