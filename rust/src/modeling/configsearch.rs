//! Generator-configuration trade-off study (paper §3.3, Figs. 3.12-3.13,
//! Tables 3.1-3.3): sweep generator configurations, score each model's
//! error against exhaustively measured ground truth and its generation
//! cost, then prune by accuracy and cost toward a default configuration.

use crate::machine::kernels::Call;
use crate::machine::Machine;
use crate::sampler::experiment::Experiment;
use crate::util::stats::Stat;

use super::generator::{generate_model, instantiate_call, ErrMeasure, GenConfig};
use super::grid::{Domain, GridKind};

/// Ground truth: minimum runtime measured on a dense multiple-of-`step`
/// grid over the domain.
pub struct GroundTruth {
    pub points: Vec<Vec<usize>>,
    pub min_seconds: Vec<f64>,
    pub reps: usize,
}

pub fn ground_truth(
    machine: &Machine,
    template: &Call,
    domain: &Domain,
    step: usize,
    reps: usize,
    seed: u64,
) -> GroundTruth {
    let mut points = Vec::new();
    let mut cursor = domain.lo.clone();
    'outer: loop {
        points.push(cursor.clone());
        for d in (0..domain.dims()).rev() {
            cursor[d] += step;
            if cursor[d] <= domain.hi[d] {
                continue 'outer;
            }
            cursor[d] = domain.lo[d].div_ceil(step) * step;
            if d == 0 {
                break 'outer;
            }
        }
    }
    // Align points to multiples of step from lo upward.
    let calls: Vec<Call> = points.iter().map(|p| instantiate_call(template, p, 5000)).collect();
    let exp = Experiment { reps, shuffle: true, warm_double_run: true, seed };
    let report = exp.run(machine, &calls);
    GroundTruth {
        points,
        min_seconds: report.per_call.iter().map(|s| s.min).collect(),
        reps,
    }
}

/// Score of one configuration.
#[derive(Clone, Debug)]
pub struct ConfigScore {
    pub cfg: GenConfig,
    /// Average relative error of the predicted minimum vs ground truth
    /// (the paper's "model error", §3.3.2).
    pub model_error: f64,
    /// Virtual seconds of measurement ("model cost").
    pub model_cost: f64,
    pub pieces: usize,
}

pub fn evaluate_config(
    machine: &Machine,
    cfg: &GenConfig,
    template: &Call,
    domain: &Domain,
    truth: &GroundTruth,
    seed: u64,
) -> ConfigScore {
    let (model, stats) = generate_model(machine, cfg, template, domain, seed);
    let mut err_sum = 0.0;
    for (p, &y) in truth.points.iter().zip(&truth.min_seconds) {
        let est = model.estimate(p).min;
        err_sum += ((est - y) / y).abs();
    }
    ConfigScore {
        cfg: cfg.clone(),
        model_error: err_sum / truth.points.len() as f64,
        model_cost: model.gen_cost,
        pieces: stats.pieces,
    }
}

/// The parameter grid of the sweep (a configurable subset of Table 3.1).
#[derive(Clone, Debug)]
pub struct SweepSpace {
    pub overfit: Vec<usize>,
    pub oversampling: Vec<usize>,
    pub grids: Vec<GridKind>,
    pub reps: Vec<usize>,
    pub ref_stats: Vec<Stat>,
    pub err_measures: Vec<ErrMeasure>,
    pub err_bounds: Vec<f64>,
    pub min_widths: Vec<usize>,
}

impl SweepSpace {
    /// Full Table 3.1 space (2880 configurations).
    pub fn full() -> SweepSpace {
        SweepSpace {
            overfit: vec![0, 1, 2],
            oversampling: (1..=10).collect(),
            grids: vec![GridKind::Cartesian, GridKind::Chebyshev],
            reps: vec![5, 10, 15],
            ref_stats: vec![Stat::Min, Stat::Med],
            err_measures: vec![ErrMeasure::P90, ErrMeasure::Max],
            err_bounds: vec![0.01, 0.02],
            min_widths: vec![32, 64],
        }
    }

    /// Reduced space for fast figure regeneration (same structure, 128
    /// configurations).
    pub fn reduced() -> SweepSpace {
        SweepSpace {
            overfit: vec![0, 2],
            oversampling: vec![2, 6],
            grids: vec![GridKind::Cartesian, GridKind::Chebyshev],
            reps: vec![5, 10],
            ref_stats: vec![Stat::Min, Stat::Med],
            err_measures: vec![ErrMeasure::P90, ErrMeasure::Max],
            err_bounds: vec![0.01, 0.02],
            min_widths: vec![32],
        }
    }

    pub fn enumerate(&self) -> Vec<GenConfig> {
        let mut out = Vec::new();
        for &overfit in &self.overfit {
            for &oversampling in &self.oversampling {
                for &grid in &self.grids {
                    for &reps in &self.reps {
                        for &ref_stat in &self.ref_stats {
                            for &err_measure in &self.err_measures {
                                for &err_bound in &self.err_bounds {
                                    for &min_width in &self.min_widths {
                                        out.push(GenConfig {
                                            overfit,
                                            oversampling,
                                            grid,
                                            reps,
                                            ref_stat,
                                            err_measure,
                                            err_bound,
                                            min_width,
                                            ..GenConfig::default()
                                        });
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// Result of the paper's two-step pruning (§3.3.2): accuracy within 1.5x of
/// best per setup, then cheapest quartile.
pub struct PruneResult {
    pub all: Vec<ConfigScore>,
    pub after_accuracy: Vec<usize>,
    pub after_cost: Vec<usize>,
    /// Majority-vote default configuration over the survivors.
    pub default_cfg: GenConfig,
}

pub fn prune(scores: Vec<ConfigScore>) -> PruneResult {
    let best_err = scores
        .iter()
        .map(|s| s.model_error)
        .fold(f64::INFINITY, f64::min);
    let after_accuracy: Vec<usize> = scores
        .iter()
        .enumerate()
        .filter(|(_, s)| s.model_error <= 1.5 * best_err)
        .map(|(i, _)| i)
        .collect();
    // First quartile of generation cost among accuracy survivors.
    let mut costs: Vec<f64> = after_accuracy.iter().map(|&i| scores[i].model_cost).collect();
    costs.sort_by(|a, b| a.total_cmp(b));
    let q1 = costs[(costs.len().saturating_sub(1)) / 4];
    let after_cost: Vec<usize> = after_accuracy
        .iter()
        .copied()
        .filter(|&i| scores[i].model_cost <= q1)
        .collect();

    // Majority vote per parameter among survivors.
    let survivors: Vec<&ConfigScore> = after_cost.iter().map(|&i| &scores[i]).collect();
    let vote = |f: &dyn Fn(&GenConfig) -> String| -> String {
        // BTreeMap so ties break on the largest key, deterministically —
        // HashMap iteration order would make max_by_key's winner vary
        // per process.
        let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
        for s in &survivors {
            *counts.entry(f(&s.cfg)).or_default() += 1;
        }
        counts
            .into_iter()
            .max_by_key(|(_, c)| *c)
            .map(|(k, _)| k)
            .unwrap_or_default()
    };
    let mut default_cfg = GenConfig::default();
    if !survivors.is_empty() {
        default_cfg.overfit = vote(&|c: &GenConfig| c.overfit.to_string()).parse().unwrap();
        default_cfg.oversampling = vote(&|c: &GenConfig| c.oversampling.to_string()).parse().unwrap();
        default_cfg.grid = if vote(&|c: &GenConfig| c.grid.name().into()) == "cartesian" {
            GridKind::Cartesian
        } else {
            GridKind::Chebyshev
        };
        default_cfg.reps = vote(&|c: &GenConfig| c.reps.to_string()).parse().unwrap();
        default_cfg.ref_stat =
            Stat::parse(&vote(&|c: &GenConfig| c.ref_stat.name().into())).unwrap();
        default_cfg.err_bound = vote(&|c: &GenConfig| c.err_bound.to_string()).parse().unwrap();
        default_cfg.min_width = vote(&|c: &GenConfig| c.min_width.to_string()).parse().unwrap();
    }
    PruneResult { all: scores, after_accuracy, after_cost, default_cfg }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::kernels::{Diag, Flags, KernelId, Side, Trans, Uplo};
    use crate::machine::{CpuId, Elem, Library};

    fn trsm_template() -> Call {
        let mut c = Call::new(KernelId::Trsm, Elem::D);
        c.flags = Flags {
            side: Some(Side::Left),
            uplo: Some(Uplo::Lower),
            trans_a: Some(Trans::No),
            diag: Some(Diag::NonUnit),
            trans_b: None,
        };
        c
    }

    fn machine() -> Machine {
        Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1)
    }

    #[test]
    fn ground_truth_covers_grid() {
        let domain = Domain::new(vec![24, 24], vec![152, 280]);
        let gt = ground_truth(&machine(), &trsm_template(), &domain, 64, 3, 7);
        assert!(gt.points.len() >= 6);
        assert!(gt.min_seconds.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn sweep_space_sizes() {
        assert_eq!(SweepSpace::full().enumerate().len(), 2880);
        assert_eq!(SweepSpace::reduced().enumerate().len(), 128);
    }

    #[test]
    fn accurate_config_beats_sloppy_config() {
        let domain = Domain::new(vec![24, 24], vec![280, 536]);
        let m = machine();
        let gt = ground_truth(&m, &trsm_template(), &domain, 64, 5, 11);
        let sloppy = GenConfig {
            oversampling: 1,
            reps: 5,
            err_bound: 0.05,
            min_width: 512,
            ..Default::default()
        };
        let careful = GenConfig { oversampling: 5, reps: 10, ..Default::default() };
        let s1 = evaluate_config(&m, &sloppy, &trsm_template(), &domain, &gt, 3);
        let s2 = evaluate_config(&m, &careful, &trsm_template(), &domain, &gt, 3);
        assert!(s2.model_error <= s1.model_error * 1.2, "{} vs {}", s2.model_error, s1.model_error);
        assert!(s2.model_cost >= s1.model_cost);
    }

    #[test]
    fn prune_keeps_accurate_cheap_configs() {
        let mk = |err: f64, cost: f64| ConfigScore {
            cfg: GenConfig::default(),
            model_error: err,
            model_cost: cost,
            pieces: 1,
        };
        let scores = vec![mk(0.01, 10.0), mk(0.011, 1.0), mk(0.1, 0.5), mk(0.012, 2.0)];
        let res = prune(scores);
        assert_eq!(res.after_accuracy, vec![0, 1, 3]);
        assert!(res.after_cost.contains(&1));
        assert!(!res.after_cost.contains(&0));
    }

    #[test]
    fn prune_survives_nan_cost_scores() {
        // A NaN model cost (degenerate config whose evaluation produced
        // no finite timings) must not panic the quartile sort; total_cmp
        // places NaN last, so finite-cost configs still prune normally.
        let mk = |err: f64, cost: f64| ConfigScore {
            cfg: GenConfig::default(),
            model_error: err,
            model_cost: cost,
            pieces: 1,
        };
        let scores = vec![mk(0.01, f64::NAN), mk(0.011, 1.0), mk(0.012, 2.0)];
        let res = prune(scores);
        assert_eq!(res.after_accuracy, vec![0, 1, 2]);
        assert!(res.after_cost.contains(&1));
    }
}
