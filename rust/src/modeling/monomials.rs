//! Monomial bases derived from kernel complexity (paper §3.2.4, Ex. 3.12).
//!
//! The basis for a kernel's runtime polynomial is the full tensor grid of
//! exponents up to the kernel's asymptotic complexity per size dimension
//! (e.g. dtrsm_L costs m²n → exponents {0..2} × {0..1}), optionally raised
//! by the generator's *overfitting* parameter.

use crate::machine::kernels::{KernelId, Side};
use crate::machine::Call;

/// Maximum monomial count supported by the AOT fit/eval artifacts
/// (python/compile/aot.py FIT_M).
pub const MAX_MONOMIALS: usize = 24;

/// Per-dimension complexity exponents of a kernel's minimal FLOP count.
pub fn complexity_exponents(kernel: KernelId, side_left: bool) -> Vec<u8> {
    use KernelId::*;
    match kernel {
        Gemm | Larfb => vec![1, 1, 1],
        Symm | Trmm | Trsm => {
            if side_left {
                vec![2, 1]
            } else {
                vec![1, 2]
            }
        }
        Syrk | Syr2k => vec![2, 1],
        Gemv | Ger => vec![1, 1],
        Trsv => vec![2],
        Axpy | Dot | Copy | Swap | Scal | Laswp => vec![1],
        Potf2 | Trti2 | Lauu2 | Sygs2 => vec![3],
        Getf2 => vec![1, 3],
        Geqr2 => vec![1, 3],
        Larft => vec![1, 2],
        TrsylUnb => vec![2, 2],
    }
}

pub fn complexity_exponents_for(call: &Call) -> Vec<u8> {
    complexity_exponents(call.kernel, call.flags.side != Some(Side::Right))
}

/// Build the exponent table: full grid up to `base + overfit` per dim,
/// shrinking `overfit` until the monomial count fits the artifact cap
/// (paper §3.3.3 does exactly this for dgemm).
pub fn exponent_table(base: &[u8], overfit: usize) -> Vec<Vec<u8>> {
    let mut of = overfit;
    loop {
        let count: usize = base.iter().map(|&b| b as usize + of + 1).product();
        if count <= MAX_MONOMIALS || of == 0 {
            return grid(base, of);
        }
        of -= 1;
    }
}

fn grid(base: &[u8], overfit: usize) -> Vec<Vec<u8>> {
    let caps: Vec<usize> = base.iter().map(|&b| b as usize + overfit).collect();
    let mut out = vec![vec![]];
    for cap in caps {
        let mut next = Vec::new();
        for stem in &out {
            for e in 0..=cap {
                let mut v = stem.clone();
                v.push(e as u8);
                next.push(v);
            }
        }
        out = next;
    }
    out
}

/// Evaluate monomial j at scaled point x.
#[inline]
pub fn eval_monomial(exps: &[u8], x: &[f64]) -> f64 {
    let mut acc = 1.0;
    for (e, &xi) in exps.iter().zip(x) {
        acc *= xi.powi(*e as i32);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trsm_left_basis_matches_paper_example() {
        // Ex. 3.12: m²n with overfit 0 → 6 monomials.
        let t = exponent_table(&complexity_exponents(KernelId::Trsm, true), 0);
        assert_eq!(t.len(), 6);
        assert!(t.contains(&vec![2, 1]));
        assert!(t.contains(&vec![0, 0]));
        assert!(!t.contains(&vec![2, 2]));
    }

    #[test]
    fn trsm_overfit_one_gives_12_monomials() {
        // Ex. 3.12 second half: degree +1 per dim → 12 basis monomials.
        let t = exponent_table(&complexity_exponents(KernelId::Trsm, true), 1);
        assert_eq!(t.len(), 12);
        assert!(t.contains(&vec![3, 2]));
    }

    #[test]
    fn gemm_overfit_is_reduced_to_fit_cap() {
        // 3 dims × overfit 2 would be 4³ = 64 > 24; must shrink (§3.3.3).
        let t = exponent_table(&complexity_exponents(KernelId::Gemm, true), 2);
        assert!(t.len() <= MAX_MONOMIALS);
        assert_eq!(t.len(), 8); // falls back to overfit 0: 2³
    }

    #[test]
    fn cubic_1d_kernels() {
        let t = exponent_table(&complexity_exponents(KernelId::Potf2, true), 0);
        assert_eq!(t.len(), 4); // 1, n, n², n³
    }

    #[test]
    fn eval_monomial_basic() {
        assert_eq!(eval_monomial(&[2, 1], &[3.0, 5.0]), 45.0);
        assert_eq!(eval_monomial(&[0, 0], &[3.0, 5.0]), 1.0);
    }

    #[test]
    fn exponent_tables_have_no_duplicates() {
        for k in [KernelId::Gemm, KernelId::Trsm, KernelId::Getf2, KernelId::Potf2] {
            for of in 0..=2 {
                let t = exponent_table(&complexity_exponents(k, true), of);
                let mut seen = std::collections::HashSet::new();
                for e in &t {
                    assert!(seen.insert(e.clone()), "dup in {k:?} of={of}");
                }
            }
        }
    }
}
