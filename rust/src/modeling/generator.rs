//! Automated model generation by adaptive refinement (paper §3.2.5, §3.3),
//! structured for the parallel execution engine.
//!
//! Generation for one case (a template [`Call`]) splits into:
//!
//! 1. a pure *planning* step ([`plan_case`]) that derives everything a
//!    leaf job needs — exponent table, points per dimension, scaling,
//!    case key — from the template and configuration alone;
//! 2. independent *leaf jobs* ([`fit_leaf`]): sample the kernel on a grid
//!    over one sub-domain, fit a relative-LSQ polynomial per summary
//!    statistic, report the error measure of the reference statistic.
//!    Every leaf owns a fresh [`crate::machine::Session`] seeded from
//!    `(base seed, case key, sub-domain)`, so its result is a pure
//!    function of its inputs — byte-identical regardless of which worker
//!    runs it or in what order;
//! 3. a *round-based* refinement driver ([`generate_model_with`]): fit
//!    the root, then repeatedly split every frontier domain whose error
//!    exceeds the bound (worst error first under the piece budget) and
//!    fan the child fits out across the engine in one batch per round.
//!
//! The driver's split schedule depends only on the deterministic leaf
//! results, so `--jobs 1` and `--jobs N` produce byte-identical models;
//! the engine changes wall-clock time, never output.

use std::sync::Arc;

use crate::engine::Engine;
use crate::machine::kernels::{Call, Region, Side};
use crate::machine::{Machine, Session};
use crate::sampler::experiment::Experiment;
use crate::util::error::Result;
use crate::util::rng::splitmix64;
use crate::util::stats::{percentile, Stat};

use super::fit::{design_matrix, relative_errors, rust_fit};
use super::grid::{sample_grid, Domain, GridKind};
use super::model::{case_key, PerfModel, Piece};
use super::monomials::{complexity_exponents_for, exponent_table};

/// Error measure over the per-point relative errors (paper §3.3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrMeasure {
    Max,
    P90,
    Avg,
}

impl ErrMeasure {
    pub fn compute(self, errs: &[f64]) -> f64 {
        match self {
            ErrMeasure::Max => errs.iter().cloned().fold(0.0, f64::max),
            ErrMeasure::P90 => percentile(errs, 90.0),
            ErrMeasure::Avg => errs.iter().sum::<f64>() / errs.len().max(1) as f64,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrMeasure::Max => "max",
            ErrMeasure::P90 => "p90",
            ErrMeasure::Avg => "avg",
        }
    }
}

/// The eight generator configuration parameters (paper §3.3.1, Table 3.1).
#[derive(Clone, Debug)]
pub struct GenConfig {
    pub overfit: usize,
    pub oversampling: usize,
    pub grid: GridKind,
    pub reps: usize,
    pub ref_stat: Stat,
    pub err_measure: ErrMeasure,
    pub err_bound: f64,
    pub min_width: usize,
    /// Safety cap on pieces (the polyeval artifact holds 64 per dispatch).
    pub max_pieces: usize,
    /// Fixed leading dimension used in measurement calls (§3.1.7: a large
    /// multiple of 8 that is not a multiple of 256).
    pub fixed_ld: usize,
}

impl Default for GenConfig {
    /// The paper's selected default: line (10) of Table 3.3 — overfit 2,
    /// oversampling 4, Chebyshev, 10 repetitions, minimum reference
    /// statistic, maximum error measure, 1 % bound, width 32.
    fn default() -> GenConfig {
        GenConfig {
            overfit: 2,
            oversampling: 4,
            grid: GridKind::Chebyshev,
            reps: 10,
            ref_stat: Stat::Min,
            err_measure: ErrMeasure::Max,
            err_bound: 0.01,
            min_width: 32,
            max_pieces: 320,
            fixed_ld: 5000,
        }
    }
}

impl GenConfig {
    /// §3.3.3 adjustments: dgemm (3 size dims) drops overfitting and widens
    /// the minimum width; multi-threaded setups widen further.
    pub fn adjusted_for(template: &Call, threads: usize) -> GenConfig {
        let mut cfg = GenConfig::default();
        let dims = crate::machine::kernels::size_dims(template.kernel);
        if dims >= 3 {
            cfg.overfit = 0;
            cfg.min_width = 64;
        }
        if threads > 1 {
            cfg.min_width = if dims >= 3 { 256 } else { 64 };
        }
        cfg
    }
}

/// Generation result diagnostics.
#[derive(Clone, Debug)]
pub struct GenStats {
    pub pieces: usize,
    pub measured_points: usize,
    pub refinements: usize,
    /// Virtual seconds of kernel execution spent on measurements.
    pub cost_seconds: f64,
}

/// The size-independent planning output for one case: everything a leaf
/// fit job needs besides the sub-domain itself. Cheap to clone and share
/// across workers behind an `Arc`.
#[derive(Clone, Debug)]
pub struct GenPlan {
    pub template: Call,
    pub cfg: GenConfig,
    pub case: String,
    /// Monomial exponent table (M x dims).
    pub exps: Vec<Vec<u8>>,
    /// Sample points per dimension (degree + 1 + oversampling).
    pub ppd: Vec<usize>,
    /// Per-dimension scaling divisor applied before monomial evaluation.
    pub scale: Vec<f64>,
    pub base_seed: u64,
}

/// Pure planning step: derive the per-case fit structure (paper §3.2.3's
/// model shape) without touching the machine.
pub fn plan_case(cfg: &GenConfig, template: &Call, domain: &Domain, seed: u64) -> GenPlan {
    let base = complexity_exponents_for(template);
    assert_eq!(
        base.len(),
        domain.dims(),
        "domain dims must match kernel size dims"
    );
    let exps = exponent_table(&base, cfg.overfit);
    // Actual per-dim degree after the cap (mirrors exponent_table).
    let max_deg: Vec<usize> = (0..base.len())
        .map(|d| exps.iter().map(|e| e[d] as usize).max().unwrap_or(0))
        .collect();
    let ppd: Vec<usize> = max_deg.iter().map(|&dg| dg + 1 + cfg.oversampling).collect();
    let scale: Vec<f64> = domain.hi.iter().map(|&h| h as f64).collect();
    GenPlan {
        case: case_key(template),
        template: template.clone(),
        cfg: cfg.clone(),
        exps,
        ppd,
        scale,
        base_seed: seed,
    }
}

/// One fitted sub-domain: the output of a leaf job.
#[derive(Clone, Debug)]
pub struct FittedNode {
    pub domain: Domain,
    /// Coefficients per statistic, indexed by `Stat::ALL` order.
    pub coeffs: [Vec<f64>; 5],
    /// Error measure of the reference statistic over the sample grid.
    pub err: f64,
}

/// Per-leaf measurement accounting, merged into [`GenStats`].
#[derive(Clone, Copy, Debug)]
pub struct LeafStats {
    pub measured_points: usize,
    pub cost_seconds: f64,
}

/// Deterministic per-leaf seed: a SplitMix64 hash of the base seed, the
/// case key and the sub-domain bounds. Scheduling-independent by
/// construction.
fn leaf_seed(base: u64, case: &str, domain: &Domain) -> u64 {
    let mut state = base ^ 0x9E37_79B9_7F4A_7C15;
    for &b in case.as_bytes() {
        state ^= b as u64;
        splitmix64(&mut state);
    }
    for (&lo, &hi) in domain.lo.iter().zip(&domain.hi) {
        state ^= (lo as u64).wrapping_shl(1) ^ (hi as u64).wrapping_shl(33);
        splitmix64(&mut state);
    }
    splitmix64(&mut state)
}

/// Leaf job: measure and fit one sub-domain. Owns its session (fresh,
/// seeded from the job identity), so the result is a pure function of
/// `(machine, plan, domain)` — independent of worker scheduling.
///
/// Deliberate tradeoff vs. the old sequential generator: leaves no
/// longer share a per-case measurement memo (the Cartesian sample-reuse
/// of §3.2.2), because a shared memo would make each leaf's timings
/// depend on which sibling measured a point first — breaking the purity
/// that guarantees `--jobs` parity. Children therefore re-measure any
/// point their parent's grid also contained. Under the default Chebyshev
/// grid, parent/child node sets barely overlap, so the extra measurement
/// cost is small; `GenStats::measured_points`/`gen_cost` report the
/// actual (slightly higher) cost honestly.
pub fn fit_leaf(machine: &Machine, plan: &GenPlan, domain: &Domain) -> (FittedNode, LeafStats) {
    let cfg = &plan.cfg;
    let points = sample_grid(domain, cfg.grid, &plan.ppd);
    let calls: Vec<Call> = points
        .iter()
        .map(|p| instantiate_call(&plan.template, p, cfg.fixed_ld))
        .collect();
    let seed = leaf_seed(plan.base_seed, &plan.case, domain);
    let mut session: Session = machine.session(seed);
    session.warmup();
    let exp = Experiment {
        reps: cfg.reps,
        shuffle: true,
        warm_double_run: true,
        seed: seed ^ 0xC0FFEE,
    };
    let report = exp.run_in(&mut session, &calls);

    let pts_scaled: Vec<Vec<f64>> = points
        .iter()
        .map(|p| p.iter().zip(&plan.scale).map(|(&v, &s)| v as f64 / s).collect())
        .collect();
    let mut coeffs: [Vec<f64>; 5] = Default::default();
    let mut ref_errs = Vec::new();
    for (si, stat) in Stat::ALL.iter().enumerate() {
        let ys: Vec<f64> = report.per_call.iter().map(|s| s.get(*stat).max(1e-12)).collect();
        let x = design_matrix(&pts_scaled, &ys, &plan.exps);
        let beta = rust_fit(&x, points.len(), plan.exps.len());
        if *stat == cfg.ref_stat {
            ref_errs = relative_errors(&pts_scaled, &ys, &plan.exps, &beta);
        }
        coeffs[si] = beta;
    }
    let err = cfg.err_measure.compute(&ref_errs);
    (
        FittedNode { domain: domain.clone(), coeffs, err },
        LeafStats { measured_points: points.len(), cost_seconds: report.virtual_seconds },
    )
}

/// Fan one round of leaf fits out across the engine, merging accounting.
fn run_fits(
    engine: &Engine,
    machine: &Arc<Machine>,
    plan: &Arc<GenPlan>,
    domains: Vec<Domain>,
    stats: &mut GenStats,
) -> Result<Vec<FittedNode>> {
    stats.refinements += domains.len();
    let span = crate::obs::trace::begin("model.gen_round", "", &plan.case);
    let tasks: Vec<_> = domains
        .into_iter()
        .map(|d| {
            let machine = Arc::clone(machine);
            let plan = Arc::clone(plan);
            move || fit_leaf(&machine, &plan, &d)
        })
        .collect();
    let n_fits = tasks.len();
    let results = engine.run(tasks)?;
    if let Some(s) = span {
        s.num("fits", n_fits as u64).finish();
    }
    let mut out = Vec::with_capacity(results.len());
    for (node, leaf) in results {
        stats.measured_points += leaf.measured_points;
        stats.cost_seconds += leaf.cost_seconds;
        out.push(node);
    }
    Ok(out)
}

/// Generate a model for `template`'s case over `domain` on `machine`,
/// fanning leaf fits out across `engine`.
///
/// Worst-error-first refinement in rounds: every round selects the
/// frontier nodes above the error bound (worst first, capped by the piece
/// budget), splits each once, and fits all children as one parallel
/// batch. This keeps quality uniform when the piece cap bites — the same
/// property the paper's worst-first strategy has — while exposing every
/// child fit of a round as an independent job.
pub fn generate_model_with(
    engine: &Engine,
    machine: &Machine,
    cfg: &GenConfig,
    template: &Call,
    domain: &Domain,
    seed: u64,
) -> Result<(PerfModel, GenStats)> {
    let plan = Arc::new(plan_case(cfg, template, domain, seed));
    let machine = Arc::new(machine.clone());
    let mut stats =
        GenStats { pieces: 0, measured_points: 0, refinements: 0, cost_seconds: 0.0 };
    let mut frontier = run_fits(engine, &machine, &plan, vec![domain.clone()], &mut stats)?;
    loop {
        // Worst splittable nodes above the bound, within the piece budget
        // (each split is net +1 piece). Ties break on frontier position,
        // keeping the schedule fully deterministic.
        let budget = cfg.max_pieces.saturating_sub(frontier.len());
        let mut cand: Vec<usize> = (0..frontier.len())
            .filter(|&i| {
                frontier[i].err > cfg.err_bound
                    && frontier[i].domain.split(cfg.min_width).is_some()
            })
            .collect();
        cand.sort_by(|&a, &b| {
            frontier[b].err.total_cmp(&frontier[a].err).then(a.cmp(&b))
        });
        cand.truncate(budget);
        if cand.is_empty() {
            break;
        }
        let chosen: std::collections::HashSet<usize> = cand.iter().copied().collect();
        let mut children = Vec::with_capacity(cand.len() * 2);
        for &i in &cand {
            let (a, b) = frontier[i].domain.split(cfg.min_width).unwrap();
            children.push(a);
            children.push(b);
        }
        let fitted = run_fits(engine, &machine, &plan, children, &mut stats)?;
        let mut next: Vec<FittedNode> = frontier
            .into_iter()
            .enumerate()
            .filter(|(i, _)| !chosen.contains(i))
            .map(|(_, nd)| nd)
            .collect();
        next.extend(fitted);
        frontier = next;
    }
    stats.pieces = frontier.len();
    let pieces: Vec<Piece> = frontier
        .into_iter()
        .map(|nd| Piece { domain: nd.domain, coeffs: nd.coeffs })
        .collect();
    let model = PerfModel {
        case: plan.case.clone(),
        exps: plan.exps.clone(),
        scale: plan.scale.clone(),
        pieces,
        gen_cost: stats.cost_seconds,
        ..Default::default()
    };
    Ok((model, stats))
}

/// Sequential wrapper around [`generate_model_with`] (the historical
/// entry point: inline execution, no worker threads). A panic inside a
/// leaf fit is re-raised here with its original message attached — the
/// engine converts it to an error, this wrapper restores the historical
/// panicking behavior.
pub fn generate_model(
    machine: &Machine,
    cfg: &GenConfig,
    template: &Call,
    domain: &Domain,
    seed: u64,
) -> (PerfModel, GenStats) {
    generate_model_with(&Engine::sequential(), machine, cfg, template, domain, seed)
        .unwrap_or_else(|e| panic!("model generation failed: {e}"))
}

/// Public variant of the sample-call construction (used by the config
/// search and tests).
pub fn instantiate_call(template: &Call, point: &[usize], fixed_ld: usize) -> Call {
    let mut call = template.clone();
    // Map the model-domain point back onto (m, n, k) — the exact inverse
    // of Call::sizes().
    call.set_sizes(point);
    call.lda = fixed_ld;
    call.ldb = fixed_ld;
    call.ldc = fixed_ld;
    synthesize_operands(&mut call);
    call
}

/// Attach synthetic operand regions matching a call's semantics: stable
/// matrix ids per slot so a double-run warm-up leaves them in cache (paper
/// §3.1.6 in-cache convention). Used by the model generator and by pure
/// in-/out-of-cache micro-timings.
pub fn synthesize_operands(call: &mut Call) {
    call.operands.clear();
    let elem = call.elem;
    let side_left = call.flags.side != Some(Side::Right);
    let trans_a = call.flags.trans_a == Some(crate::machine::kernels::Trans::Yes);
    for slot in 0..3u8 {
        let (rows, cols) = crate::sampler::signatures::mat_shape(
            call.kernel,
            slot,
            call.m,
            call.n,
            call.k,
            side_left,
            trans_a,
        );
        if rows > 0 && cols > 0 {
            call.operands.push(Region::new(0xA110C + slot as u64, 0, 0, rows, cols, elem));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::kernels::{Diag, Flags, KernelId, Trans, Uplo};
    use crate::machine::{CpuId, Elem, Library};

    fn trsm_template() -> Call {
        let mut c = Call::new(KernelId::Trsm, Elem::D);
        c.flags = Flags {
            side: Some(Side::Left),
            uplo: Some(Uplo::Lower),
            trans_a: Some(Trans::No),
            diag: Some(Diag::NonUnit),
            trans_b: None,
        };
        c
    }

    fn machine() -> Machine {
        Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1)
    }

    fn quick_cfg() -> GenConfig {
        GenConfig { reps: 5, oversampling: 2, err_bound: 0.02, ..Default::default() }
    }

    #[test]
    fn generates_piecewise_model_for_dtrsm() {
        let domain = Domain::new(vec![24, 24], vec![536, 1048]);
        let (model, stats) = generate_model(&machine(), &quick_cfg(), &trsm_template(), &domain, 1);
        assert!(!model.pieces.is_empty());
        assert!(stats.measured_points > 0);
        assert!(model.gen_cost > 0.0);
        // Pieces tile the domain: every multiple-of-8 point is covered.
        for &m in &[24, 256, 536] {
            for &n in &[24, 512, 1048] {
                let est = model.estimate(&[m, n]);
                assert!(est.med > 0.0, "({m},{n})");
            }
        }
    }

    #[test]
    fn model_is_accurate_on_unseen_points() {
        let domain = Domain::new(vec![24, 24], vec![536, 1048]);
        let mach = machine();
        let (model, _) = generate_model(&mach, &GenConfig::default(), &trsm_template(), &domain, 1);
        // Validate against warm deterministic timings on off-grid points.
        let mut session = mach.session(99);
        session.warmup();
        let mut worst: f64 = 0.0;
        for &(m, n) in &[(120, 700), (312, 136), (480, 1000), (56, 56), (264, 888)] {
            let call = instantiate_call(&trsm_template(), &[m, n], 5000);
            let truth = session.warm_seconds(&call);
            let est = model.estimate(&[m, n]).min;
            let err = ((est - truth) / truth).abs();
            worst = worst.max(err);
        }
        assert!(worst < 0.08, "worst rel err {worst}");
    }

    #[test]
    fn refinement_terminates_on_min_width() {
        let cfg = GenConfig {
            err_bound: 0.0, // unreachable: forces min-width termination
            min_width: 256,
            reps: 5,
            oversampling: 1,
            ..Default::default()
        };
        let domain = Domain::new(vec![24], vec![536]);
        let mut t = Call::new(KernelId::Potf2, Elem::D);
        t.flags.uplo = Some(Uplo::Lower);
        let (model, _) = generate_model(&machine(), &cfg, &t, &domain, 2);
        assert!(model.pieces.len() <= 4, "pieces={}", model.pieces.len());
        assert!(!model.pieces.is_empty());
    }

    #[test]
    fn pieces_tile_domain_without_gaps() {
        let domain = Domain::new(vec![24], vec![1048]);
        let mut t = Call::new(KernelId::Potf2, Elem::D);
        t.flags.uplo = Some(Uplo::Lower);
        let (model, _) = generate_model(&machine(), &quick_cfg(), &t, &domain, 3);
        for n in (24..=1048).step_by(8) {
            let covered = model.pieces.iter().any(|p| p.domain.contains(&[n]));
            assert!(covered, "n={n} uncovered");
        }
    }

    #[test]
    fn parallel_generation_is_deterministic_across_job_counts() {
        let domain = Domain::new(vec![24, 24], vec![536, 1048]);
        let mach = machine();
        let cfg = quick_cfg();
        let (seq, seq_stats) = generate_model_with(
            &Engine::sequential(),
            &mach,
            &cfg,
            &trsm_template(),
            &domain,
            9,
        )
        .unwrap();
        for jobs in [2, 4] {
            let eng = Engine::new(jobs);
            let (par, par_stats) =
                generate_model_with(&eng, &mach, &cfg, &trsm_template(), &domain, 9).unwrap();
            assert_eq!(seq, par, "jobs={jobs}");
            // Byte-for-byte identical serialization, and identical cost
            // accounting (the sums commute because each leaf's numbers
            // are merged in submission order).
            assert_eq!(seq.to_json().render(), par.to_json().render(), "jobs={jobs}");
            assert_eq!(seq_stats.measured_points, par_stats.measured_points);
        }
    }

    #[test]
    fn leaf_seed_depends_on_case_and_domain() {
        let d1 = Domain::new(vec![24], vec![536]);
        let d2 = Domain::new(vec![24], vec![528]);
        assert_ne!(leaf_seed(1, "dtrsm_LLNN_a1", &d1), leaf_seed(1, "dtrsm_LLNN_a1", &d2));
        assert_ne!(leaf_seed(1, "dtrsm_LLNN_a1", &d1), leaf_seed(1, "dpotf2_L_a1", &d1));
        assert_ne!(leaf_seed(1, "dtrsm_LLNN_a1", &d1), leaf_seed(2, "dtrsm_LLNN_a1", &d1));
        assert_eq!(leaf_seed(7, "x", &d1), leaf_seed(7, "x", &d1));
    }

    #[test]
    fn gemm_config_adjustment_applies() {
        let g = Call::new(KernelId::Gemm, Elem::D);
        let cfg = GenConfig::adjusted_for(&g, 1);
        assert_eq!(cfg.overfit, 0);
        assert_eq!(cfg.min_width, 64);
        let cfg_mt = GenConfig::adjusted_for(&g, 12);
        assert_eq!(cfg_mt.min_width, 256);
    }

    #[test]
    fn instantiate_sets_sizes_and_operands() {
        let c = instantiate_call(&trsm_template(), &[128, 512], 5000);
        assert_eq!((c.m, c.n), (128, 512));
        assert_eq!(c.lda, 5000);
        assert_eq!(c.operands.len(), 2);
        assert_eq!(c.operands[0].rows, 128); // A is m x m for side=L
        assert_eq!(c.operands[1].cols, 512);
    }
}
