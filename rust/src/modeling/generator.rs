//! Automated model generation by adaptive refinement (paper §3.2.5, §3.3).
//!
//! For one case (a template [`Call`]) and size domain, the generator
//! samples the kernel on a grid, fits a relative-LSQ polynomial per
//! summary statistic, and recursively splits the domain until the error
//! measure of the *reference statistic* falls below the target bound or
//! the domain is narrower than the minimum width.

use std::collections::HashMap;

use crate::machine::kernels::{Call, Region, Side};
use crate::machine::{Machine, Session};
use crate::sampler::experiment::Experiment;
use crate::util::stats::{percentile, Stat, Summary};

use super::fit::{design_matrix, relative_errors, rust_fit};
use super::grid::{sample_grid, Domain, GridKind};
use super::model::{case_key, PerfModel, Piece};
use super::monomials::{complexity_exponents_for, exponent_table};

/// Error measure over the per-point relative errors (paper §3.3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrMeasure {
    Max,
    P90,
    Avg,
}

impl ErrMeasure {
    pub fn compute(self, errs: &[f64]) -> f64 {
        match self {
            ErrMeasure::Max => errs.iter().cloned().fold(0.0, f64::max),
            ErrMeasure::P90 => percentile(errs, 90.0),
            ErrMeasure::Avg => errs.iter().sum::<f64>() / errs.len().max(1) as f64,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrMeasure::Max => "max",
            ErrMeasure::P90 => "p90",
            ErrMeasure::Avg => "avg",
        }
    }
}

/// The eight generator configuration parameters (paper §3.3.1, Table 3.1).
#[derive(Clone, Debug)]
pub struct GenConfig {
    pub overfit: usize,
    pub oversampling: usize,
    pub grid: GridKind,
    pub reps: usize,
    pub ref_stat: Stat,
    pub err_measure: ErrMeasure,
    pub err_bound: f64,
    pub min_width: usize,
    /// Safety cap on pieces (the polyeval artifact holds 64 per dispatch).
    pub max_pieces: usize,
    /// Fixed leading dimension used in measurement calls (§3.1.7: a large
    /// multiple of 8 that is not a multiple of 256).
    pub fixed_ld: usize,
}

impl Default for GenConfig {
    /// The paper's selected default: line (10) of Table 3.3 — overfit 2,
    /// oversampling 4, Chebyshev, 10 repetitions, minimum reference
    /// statistic, maximum error measure, 1 % bound, width 32.
    fn default() -> GenConfig {
        GenConfig {
            overfit: 2,
            oversampling: 4,
            grid: GridKind::Chebyshev,
            reps: 10,
            ref_stat: Stat::Min,
            err_measure: ErrMeasure::Max,
            err_bound: 0.01,
            min_width: 32,
            max_pieces: 320,
            fixed_ld: 5000,
        }
    }
}

impl GenConfig {
    /// §3.3.3 adjustments: dgemm (3 size dims) drops overfitting and widens
    /// the minimum width; multi-threaded setups widen further.
    pub fn adjusted_for(template: &Call, threads: usize) -> GenConfig {
        let mut cfg = GenConfig::default();
        let dims = crate::machine::kernels::size_dims(template.kernel);
        if dims >= 3 {
            cfg.overfit = 0;
            cfg.min_width = 64;
        }
        if threads > 1 {
            cfg.min_width = if dims >= 3 { 256 } else { 64 };
        }
        cfg
    }
}

/// Generation result diagnostics.
#[derive(Clone, Debug)]
pub struct GenStats {
    pub pieces: usize,
    pub measured_points: usize,
    pub refinements: usize,
    /// Virtual seconds of kernel execution spent on measurements.
    pub cost_seconds: f64,
}

/// Generate a model for `template`'s case over `domain` on `machine`.
pub fn generate_model(
    machine: &Machine,
    cfg: &GenConfig,
    template: &Call,
    domain: &Domain,
    seed: u64,
) -> (PerfModel, GenStats) {
    let base = complexity_exponents_for(template);
    assert_eq!(
        base.len(),
        domain.dims(),
        "domain dims must match kernel size dims"
    );
    let exps = exponent_table(&base, cfg.overfit);
    // Actual per-dim degree after the cap (mirrors exponent_table).
    let max_deg: Vec<usize> = (0..base.len())
        .map(|d| exps.iter().map(|e| e[d] as usize).max().unwrap_or(0))
        .collect();
    let ppd: Vec<usize> = max_deg.iter().map(|&dg| dg + 1 + cfg.oversampling).collect();
    let scale: Vec<f64> = domain.hi.iter().map(|&h| h as f64).collect();

    let mut gen = GenCtx {
        machine,
        cfg,
        template,
        exps: &exps,
        ppd: &ppd,
        scale: &scale,
        session: machine.session(seed),
        cache: HashMap::new(),
        stats: GenStats { pieces: 0, measured_points: 0, refinements: 0, cost_seconds: 0.0 },
        pieces: Vec::new(),
    };
    gen.session.warmup();
    gen.refine(domain.clone());

    let stats = GenStats { pieces: gen.pieces.len(), ..gen.stats };
    let pieces = std::mem::take(&mut gen.pieces);
    let cost = gen.stats.cost_seconds;
    drop(gen);
    (
        PerfModel { case: case_key(template), exps, scale, pieces, gen_cost: cost, ..Default::default() },
        stats,
    )
}

struct FittedNode {
    domain: Domain,
    coeffs: [Vec<f64>; 5],
    err: f64,
}

struct GenCtx<'a> {
    #[allow(dead_code)]
    machine: &'a Machine,
    cfg: &'a GenConfig,
    template: &'a Call,
    exps: &'a [Vec<u8>],
    ppd: &'a [usize],
    scale: &'a [f64],
    session: Session,
    /// Measurement cache: point -> summary (gives Cartesian grids their
    /// sample-reuse advantage automatically, §3.2.2).
    cache: HashMap<Vec<usize>, Summary>,
    stats: GenStats,
    pieces: Vec<Piece>,
}

impl GenCtx<'_> {
    /// Worst-error-first refinement: fit every frontier domain, repeatedly
    /// split the one with the largest error measure. This keeps quality
    /// uniform if the piece cap is reached (a depth-first recursion would
    /// spend the whole budget on one corner of the domain).
    fn refine(&mut self, root: Domain) {
        let first = self.fit_domain(root);
        let mut frontier: Vec<FittedNode> = vec![first];
        loop {
            // Find the worst splittable node above the bound.
            let worst = frontier
                .iter()
                .enumerate()
                .filter(|(_, nd)| {
                    nd.err > self.cfg.err_bound
                        && nd.domain.split(self.cfg.min_width).is_some()
                })
                .max_by(|a, b| a.1.err.partial_cmp(&b.1.err).unwrap())
                .map(|(i, _)| i);
            let Some(idx) = worst else { break };
            if frontier.len() + 1 > self.cfg.max_pieces {
                break;
            }
            let node = frontier.swap_remove(idx);
            let (a, b) = node.domain.split(self.cfg.min_width).unwrap();
            frontier.push(self.fit_domain(a));
            frontier.push(self.fit_domain(b));
        }
        self.pieces
            .extend(frontier.into_iter().map(|nd| Piece { domain: nd.domain, coeffs: nd.coeffs }));
    }

    fn fit_domain(&mut self, domain: Domain) -> FittedNode {
        self.stats.refinements += 1;
        let points = sample_grid(&domain, self.cfg.grid, self.ppd);
        self.measure_missing(&points);

        let pts_scaled: Vec<Vec<f64>> = points
            .iter()
            .map(|p| p.iter().zip(self.scale).map(|(&v, &s)| v as f64 / s).collect())
            .collect();
        let mut coeffs: [Vec<f64>; 5] = Default::default();
        let mut ref_errs = Vec::new();
        for (si, stat) in Stat::ALL.iter().enumerate() {
            let ys: Vec<f64> = points
                .iter()
                .map(|p| self.cache[p].get(*stat).max(1e-12))
                .collect();
            let x = design_matrix(&pts_scaled, &ys, self.exps);
            let beta = rust_fit(&x, points.len(), self.exps.len());
            if *stat == self.cfg.ref_stat {
                ref_errs = relative_errors(&pts_scaled, &ys, self.exps, &beta);
            }
            coeffs[si] = beta;
        }
        let err = self.cfg.err_measure.compute(&ref_errs);
        FittedNode { domain, coeffs, err }
    }

    fn measure_missing(&mut self, points: &[Vec<usize>]) {
        let missing: Vec<Vec<usize>> =
            points.iter().filter(|p| !self.cache.contains_key(*p)).cloned().collect();
        if missing.is_empty() {
            return;
        }
        let calls: Vec<Call> = missing.iter().map(|p| self.instantiate(p)).collect();
        let exp = Experiment {
            reps: self.cfg.reps,
            shuffle: true,
            warm_double_run: true,
            seed: 0xC0FFEE ^ self.stats.refinements as u64,
        };
        let report = exp.run_in(&mut self.session, &calls);
        self.stats.cost_seconds += report.virtual_seconds;
        self.stats.measured_points += missing.len();
        for (p, s) in missing.into_iter().zip(report.per_call) {
            self.cache.insert(p, s);
        }
    }

    /// Build the measurement call for a sample point: template + sizes +
    /// fixed leading dimensions + synthetic warm-able operand regions.
    fn instantiate(&self, point: &[usize]) -> Call {
        instantiate_call(self.template, point, self.cfg.fixed_ld)
    }
}

/// Public variant of the sample-call construction (used by the config
/// search and tests).
pub fn instantiate_call(template: &Call, point: &[usize], fixed_ld: usize) -> Call {
    let mut call = template.clone();
    // Map the model-domain point back onto (m, n, k) — the exact inverse
    // of Call::sizes().
    call.set_sizes(point);
    call.lda = fixed_ld;
    call.ldb = fixed_ld;
    call.ldc = fixed_ld;
    synthesize_operands(&mut call);
    call
}

/// Attach synthetic operand regions matching a call's semantics: stable
/// matrix ids per slot so a double-run warm-up leaves them in cache (paper
/// §3.1.6 in-cache convention). Used by the model generator and by pure
/// in-/out-of-cache micro-timings.
pub fn synthesize_operands(call: &mut Call) {
    call.operands.clear();
    let elem = call.elem;
    let side_left = call.flags.side != Some(Side::Right);
    let trans_a = call.flags.trans_a == Some(crate::machine::kernels::Trans::Yes);
    for slot in 0..3u8 {
        let (rows, cols) = crate::sampler::signatures::mat_shape(
            call.kernel,
            slot,
            call.m,
            call.n,
            call.k,
            side_left,
            trans_a,
        );
        if rows > 0 && cols > 0 {
            call.operands.push(Region::new(0xA110C + slot as u64, 0, 0, rows, cols, elem));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::kernels::{Diag, Flags, KernelId, Trans, Uplo};
    use crate::machine::{CpuId, Elem, Library};

    fn trsm_template() -> Call {
        let mut c = Call::new(KernelId::Trsm, Elem::D);
        c.flags = Flags {
            side: Some(Side::Left),
            uplo: Some(Uplo::Lower),
            trans_a: Some(Trans::No),
            diag: Some(Diag::NonUnit),
            trans_b: None,
        };
        c
    }

    fn machine() -> Machine {
        Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1)
    }

    fn quick_cfg() -> GenConfig {
        GenConfig { reps: 5, oversampling: 2, err_bound: 0.02, ..Default::default() }
    }

    #[test]
    fn generates_piecewise_model_for_dtrsm() {
        let domain = Domain::new(vec![24, 24], vec![536, 1048]);
        let (model, stats) = generate_model(&machine(), &quick_cfg(), &trsm_template(), &domain, 1);
        assert!(!model.pieces.is_empty());
        assert!(stats.measured_points > 0);
        assert!(model.gen_cost > 0.0);
        // Pieces tile the domain: every multiple-of-8 point is covered.
        for &m in &[24, 256, 536] {
            for &n in &[24, 512, 1048] {
                let est = model.estimate(&[m, n]);
                assert!(est.med > 0.0, "({m},{n})");
            }
        }
    }

    #[test]
    fn model_is_accurate_on_unseen_points() {
        let domain = Domain::new(vec![24, 24], vec![536, 1048]);
        let mach = machine();
        let (model, _) = generate_model(&mach, &GenConfig::default(), &trsm_template(), &domain, 1);
        // Validate against warm deterministic timings on off-grid points.
        let mut session = mach.session(99);
        session.warmup();
        let mut worst: f64 = 0.0;
        for &(m, n) in &[(120, 700), (312, 136), (480, 1000), (56, 56), (264, 888)] {
            let call = instantiate_call(&trsm_template(), &[m, n], 5000);
            let truth = session.warm_seconds(&call);
            let est = model.estimate(&[m, n]).min;
            let err = ((est - truth) / truth).abs();
            worst = worst.max(err);
        }
        assert!(worst < 0.08, "worst rel err {worst}");
    }

    #[test]
    fn refinement_terminates_on_min_width() {
        let cfg = GenConfig {
            err_bound: 0.0, // unreachable: forces min-width termination
            min_width: 256,
            reps: 5,
            oversampling: 1,
            ..Default::default()
        };
        let domain = Domain::new(vec![24], vec![536]);
        let mut t = Call::new(KernelId::Potf2, Elem::D);
        t.flags.uplo = Some(Uplo::Lower);
        let (model, _) = generate_model(&machine(), &cfg, &t, &domain, 2);
        assert!(model.pieces.len() <= 4, "pieces={}", model.pieces.len());
        assert!(!model.pieces.is_empty());
    }

    #[test]
    fn pieces_tile_domain_without_gaps() {
        let domain = Domain::new(vec![24], vec![1048]);
        let mut t = Call::new(KernelId::Potf2, Elem::D);
        t.flags.uplo = Some(Uplo::Lower);
        let (model, _) = generate_model(&machine(), &quick_cfg(), &t, &domain, 3);
        for n in (24..=1048).step_by(8) {
            let covered = model.pieces.iter().any(|p| p.domain.contains(&[n]));
            assert!(covered, "n={n} uncovered");
        }
    }

    #[test]
    fn gemm_config_adjustment_applies() {
        let g = Call::new(KernelId::Gemm, Elem::D);
        let cfg = GenConfig::adjusted_for(&g, 1);
        assert_eq!(cfg.overfit, 0);
        assert_eq!(cfg.min_width, 64);
        let cfg_mt = GenConfig::adjusted_for(&g, 12);
        assert_eq!(cfg_mt.min_width, 256);
    }

    #[test]
    fn instantiate_sets_sizes_and_operands() {
        let c = instantiate_call(&trsm_template(), &[128, 512], 5000);
        assert_eq!((c.m, c.n), (128, 512));
        assert_eq!(c.lda, 5000);
        assert_eq!(c.operands.len(), 2);
        assert_eq!(c.operands[0].rows, 128); // A is m x m for side=L
        assert_eq!(c.operands[1].cols, 512);
    }
}
