//! Performance modeling (paper Ch. 3): measurement-based piecewise
//! multivariate polynomial models of kernel runtime, generated once per
//! hardware/software setup by adaptive refinement.

pub mod configsearch;
pub mod fit;
pub mod generator;
pub mod grid;
pub mod model;
pub mod monomials;

pub use generator::{generate_model, generate_model_with, ErrMeasure, GenConfig, GenPlan};
pub use grid::{Domain, GridKind};
pub use model::{case_key, ModelStore, PerfModel};
