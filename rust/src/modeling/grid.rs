//! Sampling point distributions (paper §3.2.2): Cartesian and Chebyshev
//! grids over hyper-rectangular size domains, rounded to multiples of 8 to
//! dodge vectorization sawtooth artifacts (§3.1.5.1).

/// A hyper-rectangular domain of size arguments (inclusive bounds).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Domain {
    pub lo: Vec<usize>,
    pub hi: Vec<usize>,
}

impl Domain {
    pub fn new(lo: Vec<usize>, hi: Vec<usize>) -> Domain {
        assert_eq!(lo.len(), hi.len());
        assert!(lo.iter().zip(&hi).all(|(l, h)| l <= h), "{lo:?} > {hi:?}");
        Domain { lo, hi }
    }

    pub fn dims(&self) -> usize {
        self.lo.len()
    }

    pub fn contains(&self, x: &[usize]) -> bool {
        x.iter()
            .zip(self.lo.iter().zip(&self.hi))
            .all(|(&v, (&l, &h))| v >= l && v <= h)
    }

    /// Split along the dimension with the largest hi/lo ratio at the
    /// 8-rounded midpoint (paper §3.2.5). Returns None if every dimension
    /// is already narrower than `min_width`.
    pub fn split(&self, min_width: usize) -> Option<(Domain, Domain)> {
        let mut best: Option<(usize, f64)> = None;
        for d in 0..self.dims() {
            if self.hi[d] - self.lo[d] < min_width {
                continue;
            }
            let ratio = self.hi[d] as f64 / self.lo[d].max(1) as f64;
            if best.map(|(_, r)| ratio > r).unwrap_or(true) {
                best = Some((d, ratio));
            }
        }
        let (dim, _) = best?;
        // m_s = round((l+u)/2, 8)
        let mid = round8((self.lo[dim] + self.hi[dim]) / 2);
        let mid = mid.clamp(self.lo[dim] + 8, self.hi[dim].saturating_sub(8));
        let mut a = self.clone();
        let mut b = self.clone();
        a.hi[dim] = mid;
        b.lo[dim] = mid;
        Some((a, b))
    }
}

pub fn round8(v: usize) -> usize {
    ((v + 4) / 8) * 8
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GridKind {
    Cartesian,
    Chebyshev,
}

impl GridKind {
    pub fn name(self) -> &'static str {
        match self {
            GridKind::Cartesian => "cartesian",
            GridKind::Chebyshev => "chebyshev",
        }
    }
}

/// 1-D node positions in [0, 1] (boundary-including Chebyshev variant,
/// §3.2.2).
pub fn nodes_1d(kind: GridKind, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    match kind {
        GridKind::Cartesian => (0..n).map(|i| i as f64 / (n - 1) as f64).collect(),
        GridKind::Chebyshev => {
            // x_i = cos(i/(n-1) π) in [-1,1], mapped to [0,1], ascending.
            let mut v: Vec<f64> = (0..n)
                .map(|i| {
                    let c = (i as f64 / (n - 1) as f64 * std::f64::consts::PI).cos();
                    (1.0 - c) / 2.0
                })
                .collect();
            v.sort_by(|a, b| a.total_cmp(b));
            v
        }
    }
}

/// Full tensor-product sample grid over a domain, with `points_per_dim[d]`
/// nodes in dimension d, every coordinate rounded to a multiple of 8 (and
/// deduplicated after rounding).
pub fn sample_grid(domain: &Domain, kind: GridKind, points_per_dim: &[usize]) -> Vec<Vec<usize>> {
    assert_eq!(points_per_dim.len(), domain.dims());
    let axes: Vec<Vec<usize>> = (0..domain.dims())
        .map(|d| {
            let mut xs: Vec<usize> = nodes_1d(kind, points_per_dim[d])
                .into_iter()
                .map(|t| {
                    let v = domain.lo[d] as f64 + t * (domain.hi[d] - domain.lo[d]) as f64;
                    round8(v.round() as usize).clamp(round8(domain.lo[d]), domain.hi[d] / 8 * 8)
                })
                .collect();
            xs.dedup();
            xs
        })
        .collect();
    // Cartesian product.
    let mut out: Vec<Vec<usize>> = vec![vec![]];
    for axis in &axes {
        let mut next = Vec::with_capacity(out.len() * axis.len());
        for stem in &out {
            for &v in axis {
                let mut p = stem.clone();
                p.push(v);
                next.push(p);
            }
        }
        out = next;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_nodes_are_even() {
        let n = nodes_1d(GridKind::Cartesian, 5);
        assert_eq!(n, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
    }

    #[test]
    fn chebyshev_nodes_include_boundaries_and_cluster() {
        let n = nodes_1d(GridKind::Chebyshev, 5);
        assert!((n[0] - 0.0).abs() < 1e-12);
        assert!((n[4] - 1.0).abs() < 1e-12);
        // Denser near boundaries than in the middle.
        assert!(n[1] - n[0] < n[2] - n[1]);
    }

    #[test]
    fn chebyshev_nodes_sort_ascending_in_unit_interval() {
        // Regression guard for the node sort: strictly ascending, both
        // endpoints exact, everything inside [0, 1].
        for n in [2usize, 3, 5, 9] {
            let v = nodes_1d(GridKind::Chebyshev, n);
            assert_eq!(v.len(), n);
            assert_eq!(v[0], 0.0);
            assert_eq!(v[n - 1], 1.0);
            assert!(v.windows(2).all(|w| w[0] < w[1]), "{v:?}");
        }
    }

    #[test]
    fn grid_points_are_multiples_of_8_inside_domain() {
        let d = Domain::new(vec![24, 24], vec![536, 4152]);
        for kind in [GridKind::Cartesian, GridKind::Chebyshev] {
            let pts = sample_grid(&d, kind, &[6, 5]);
            assert!(!pts.is_empty());
            for p in &pts {
                assert!(p.iter().all(|v| v % 8 == 0), "{p:?}");
                assert!(d.contains(p), "{p:?}");
            }
        }
    }

    #[test]
    fn grid_size_is_product_of_axis_counts() {
        let d = Domain::new(vec![24], vec![536]);
        let pts = sample_grid(&d, GridKind::Cartesian, &[6]);
        assert_eq!(pts.len(), 6);
    }

    #[test]
    fn cartesian_children_reuse_parent_points() {
        // §3.2.2: splitting a Cartesian grid in half reuses all points.
        let d = Domain::new(vec![0], vec![512]);
        let parent: std::collections::HashSet<_> =
            sample_grid(&d, GridKind::Cartesian, &[5]).into_iter().collect();
        let (a, _) = d.split(8).unwrap();
        let child = sample_grid(&a, GridKind::Cartesian, &[5]);
        let reused = child.iter().filter(|p| parent.contains(*p)).count();
        assert!(reused >= 3, "reused={reused}");
    }

    #[test]
    fn split_prefers_relatively_largest_dim() {
        let d = Domain::new(vec![24, 24], vec![536, 4152]);
        let (a, b) = d.split(64).unwrap();
        // n (dim 1) has the larger hi/lo ratio -> split there at ~2088.
        assert_eq!(a.hi[0], 536);
        assert_eq!(a.hi[1], 2088);
        assert_eq!(b.lo[1], 2088);
    }

    #[test]
    fn split_stops_below_min_width() {
        let d = Domain::new(vec![24, 24], vec![56, 56]);
        assert!(d.split(64).is_none());
    }

    #[test]
    fn round8_behaviour() {
        assert_eq!(round8(2088), 2088);
        assert_eq!(round8(2085), 2088);
        assert_eq!(round8(3), 0);
    }
}
