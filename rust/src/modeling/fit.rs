//! Relative least-squares polynomial fitting (paper §3.2.4).
//!
//! Minimizes Σ((y_i - p(x_i))/y_i)² over polynomial coefficients via the
//! normal equations (XᵀX)β = Xᵀ1 with X[i,j] = m_j(x_i)/y_i — exactly the
//! paper's formulation. Two interchangeable backends:
//!
//! * [`rust_fit`] — in-process Gauss-Jordan solve (mirrors the L2 graph);
//! * `runtime::Runtime::fit` — the AOT artifact entry point (portable
//!   in-process backend; PJRT in an XLA-enabled build).
//!
//! Both consume the same scaled design matrix built by [`design_matrix`].

use super::monomials::eval_monomial;

/// Build the scaled design matrix X (row-major, n x m) for points already
/// mapped into the fit's scaled coordinates.
pub fn design_matrix(pts: &[Vec<f64>], ys: &[f64], exps: &[Vec<u8>]) -> Vec<f64> {
    let (n, m) = (pts.len(), exps.len());
    let mut x = vec![0.0; n * m];
    for (i, (p, &y)) in pts.iter().zip(ys).enumerate() {
        debug_assert!(y > 0.0, "nonpositive measurement {y}");
        for (j, e) in exps.iter().enumerate() {
            x[i * m + j] = eval_monomial(e, p) / y;
        }
    }
    x
}

/// Solve min ‖1 − Xβ‖² for X row-major (n x m). Pure-Rust backend.
pub fn rust_fit(x: &[f64], n: usize, m: usize) -> Vec<f64> {
    // G = XᵀX, b = Xᵀ1.
    let mut g = vec![0.0; m * m];
    let mut b = vec![0.0; m];
    for i in 0..n {
        let row = &x[i * m..(i + 1) * m];
        for j in 0..m {
            b[j] += row[j];
            for l in j..m {
                g[j * m + l] += row[j] * row[l];
            }
        }
    }
    for j in 0..m {
        for l in 0..j {
            g[j * m + l] = g[l * m + j];
        }
    }
    spd_solve(&mut g, &mut b, m);
    b
}

/// In-place unpivoted Gauss-Jordan solve of the (ridged) SPD system —
/// the same algorithm the L2 JAX graph lowers (python/compile/model.py).
pub fn spd_solve(g: &mut [f64], b: &mut [f64], m: usize) {
    // Relative ridge for rank-deficient systems (padded columns).
    let trace: f64 = (0..m).map(|j| g[j * m + j]).sum();
    let ridge = 1e-11 * trace / m as f64;
    for j in 0..m {
        g[j * m + j] += ridge;
    }
    for k in 0..m {
        let pivot = g[k * m + k];
        let pivot = if pivot.abs() < 1e-300 { 1e-300 } else { pivot };
        // Normalize row k.
        for l in 0..m {
            g[k * m + l] /= pivot;
        }
        b[k] /= pivot;
        // Eliminate column k from all other rows.
        for i in 0..m {
            if i == k {
                continue;
            }
            let f = g[i * m + k];
            if f == 0.0 {
                continue;
            }
            for l in 0..m {
                g[i * m + l] -= f * g[k * m + l];
            }
            b[i] -= f * b[k];
        }
    }
}

/// Point-wise absolute relative errors |y_i − p(x_i)|/y_i of a fit.
pub fn relative_errors(
    pts: &[Vec<f64>],
    ys: &[f64],
    exps: &[Vec<u8>],
    beta: &[f64],
) -> Vec<f64> {
    pts.iter()
        .zip(ys)
        .map(|(p, &y)| {
            let pred: f64 = exps
                .iter()
                .zip(beta)
                .map(|(e, &c)| c * eval_monomial(e, p))
                .sum();
            ((y - pred) / y).abs()
        })
        .collect()
}

/// Evaluate a fitted polynomial at a scaled point.
pub fn eval_poly(exps: &[Vec<u8>], beta: &[f64], x: &[f64]) -> f64 {
    exps.iter()
        .zip(beta)
        .map(|(e, &c)| c * eval_monomial(e, x))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cubic_exps() -> Vec<Vec<u8>> {
        (0..4u8).map(|e| vec![e]).collect()
    }

    #[test]
    fn recovers_exact_cubic() {
        let exps = cubic_exps();
        // Strictly positive generating polynomial (runtimes are positive).
        let truth = [1.0, 0.5, 2.0, 3.0];
        let pts: Vec<Vec<f64>> = (1..=20).map(|i| vec![i as f64 / 20.0]).collect();
        let ys: Vec<f64> = pts.iter().map(|p| eval_poly(&exps, &truth, p)).collect();
        let x = design_matrix(&pts, &ys, &exps);
        let beta = rust_fit(&x, pts.len(), exps.len());
        // The tiny stabilizing ridge bounds coefficient recovery around
        // ~1e-6 on this conditioning; the *relative fit error* is what the
        // paper's pipeline consumes.
        for (b, t) in beta.iter().zip(truth) {
            assert!((b - t).abs() < 1e-4, "{beta:?}");
        }
        let errs = relative_errors(&pts, &ys, &exps, &beta);
        assert!(errs.iter().all(|&e| e < 1e-6), "{errs:?}");
    }

    #[test]
    fn relative_weighting_prioritizes_small_values() {
        // Two clusters: small values with +5% noise would dominate an
        // absolute-LSQ fit's relative error; relative LSQ keeps both ~equal.
        let exps = vec![vec![0u8], vec![1u8]];
        let pts: Vec<Vec<f64>> = (1..=40).map(|i| vec![i as f64 / 40.0]).collect();
        let ys: Vec<f64> = pts.iter().map(|p| 0.01 + p[0] * 10.0).collect();
        let x = design_matrix(&pts, &ys, &exps);
        let beta = rust_fit(&x, pts.len(), exps.len());
        let errs = relative_errors(&pts, &ys, &exps, &beta);
        assert!(errs.iter().all(|&e| e < 1e-6), "{errs:?}");
    }

    #[test]
    fn bivariate_trsm_style_fit() {
        // y = m²n cost surface with mild size-dependent efficiency.
        let exps: Vec<Vec<u8>> = (0..3u8)
            .flat_map(|i| (0..2u8).map(move |j| vec![i, j]))
            .collect();
        let mut rng = Rng::new(5);
        let pts: Vec<Vec<f64>> = (0..60)
            .map(|_| vec![rng.range_f64(0.05, 1.0), rng.range_f64(0.05, 1.0)])
            .collect();
        let ys: Vec<f64> = pts
            .iter()
            .map(|p| (p[0] * p[0] * p[1] + 0.01) * (1.0 + 0.1 * p[0]))
            .collect();
        let x = design_matrix(&pts, &ys, &exps);
        let beta = rust_fit(&x, pts.len(), exps.len());
        let errs = relative_errors(&pts, &ys, &exps, &beta);
        let avg = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(avg < 0.02, "avg={avg}");
    }

    #[test]
    fn zero_columns_get_zero_coefficients() {
        let exps = vec![vec![0u8], vec![1u8], vec![7u8]]; // x^7 ~ 0 on small x... use literal zero col
        let pts: Vec<Vec<f64>> = (1..=10).map(|i| vec![i as f64 / 10.0]).collect();
        let ys: Vec<f64> = pts.iter().map(|p| 1.0 + p[0]).collect();
        let mut x = design_matrix(&pts, &ys, &exps);
        // Zero out the third column entirely (simulates padding).
        for i in 0..pts.len() {
            x[i * 3 + 2] = 0.0;
        }
        let beta = rust_fit(&x, pts.len(), 3);
        assert!(beta[2].abs() < 1e-6, "{beta:?}");
        assert!((beta[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn spd_solve_matches_manual_solution() {
        // g = [[4,2],[2,3]], b = [10, 9] -> x = [12/8? compute: solve.
        let mut g = vec![4.0, 2.0, 2.0, 3.0];
        let mut b = vec![10.0, 9.0];
        spd_solve(&mut g, &mut b, 2);
        // 4x+2y=10, 2x+3y=9 -> x=1.5, y=2.
        assert!((b[0] - 1.5).abs() < 1e-9);
        assert!((b[1] - 2.0).abs() < 1e-9);
    }
}
