//! CLI smoke tests: exercise the `dlapm` binary end-to-end so `main.rs`
//! is covered by `cargo test`.

use std::process::Command;

fn dlapm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dlapm"))
}

#[test]
fn help_exits_successfully() {
    let out = dlapm().arg("help").output().expect("spawning dlapm");
    assert!(out.status.success(), "exit: {:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("subcommands:"), "{text}");
    assert!(text.contains("figures"), "{text}");
}

#[test]
fn no_arguments_prints_help() {
    let out = dlapm().output().expect("spawning dlapm");
    assert!(out.status.success(), "exit: {:?}", out.status);
    assert!(String::from_utf8_lossy(&out.stdout).contains("subcommands:"));
}

#[test]
fn list_exits_successfully_and_names_figures() {
    let out = dlapm().arg("list").output().expect("spawning dlapm");
    assert!(out.status.success(), "exit: {:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("figure ids:"), "{text}");
    assert!(text.contains("fig4_12"), "{text}");
    assert!(text.contains("haswell"), "{text}");
}

#[test]
fn help_documents_gen_and_jobs() {
    let out = dlapm().arg("help").output().expect("spawning dlapm");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("gen"), "{text}");
    assert!(text.contains("--jobs"), "{text}");
    assert!(text.contains("--all"), "{text}");
}

/// End-to-end `--jobs` parity through the real binary: `gen --jobs 1`
/// and `gen --jobs 4` write byte-identical model stores.
#[test]
fn gen_jobs_parity_byte_for_byte() {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos())
        .unwrap_or(0);
    let dir = std::env::temp_dir().join(format!("dlapm_cli_gen_{}_{nanos}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    struct Cleanup(std::path::PathBuf);
    impl Drop for Cleanup {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }
    let _cleanup = Cleanup(dir.clone());

    let gen = |jobs: &str, file: &str| {
        let path = dir.join(file);
        let out = dlapm()
            .args([
                "gen", "--op", "potrf", "--cpu", "sandybridge", "--lib", "openblas",
                "--max-n", "536", "--max-b", "104", "--seed", "5", "--jobs", jobs, "--out",
            ])
            .arg(&path)
            .output()
            .expect("spawning dlapm gen");
        assert!(out.status.success(), "gen --jobs {jobs}: {:?}", out.status);
        std::fs::read(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
    };
    let a = gen("1", "jobs1.json");
    let b = gen("4", "jobs4.json");
    assert!(!a.is_empty());
    assert_eq!(a, b, "gen --jobs 1 and --jobs 4 must write identical stores");
}
