//! CLI smoke tests: exercise the `dlapm` binary end-to-end so `main.rs`
//! is covered by `cargo test`.

use std::process::Command;

mod common;
use common::TempDir;

fn dlapm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dlapm"))
}

/// The selection-table rows of a stdout capture (lines like
/// `"  1. alg  0.123 ms"`), i.e. the ranking output the warm-start
/// acceptance criterion requires to be byte-identical cold vs warm.
fn ranking_rows(stdout: &[u8]) -> Vec<String> {
    String::from_utf8_lossy(stdout)
        .lines()
        .filter(|l| {
            let t = l.trim_start();
            t.split_once('.')
                .map(|(rank, _)| !rank.is_empty() && rank.chars().all(|c| c.is_ascii_digit()))
                .unwrap_or(false)
        })
        .map(|l| l.to_string())
        .collect()
}

#[test]
fn help_exits_successfully() {
    let out = dlapm().arg("help").output().expect("spawning dlapm");
    assert!(out.status.success(), "exit: {:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("subcommands:"), "{text}");
    assert!(text.contains("figures"), "{text}");
}

#[test]
fn no_arguments_prints_help() {
    let out = dlapm().output().expect("spawning dlapm");
    assert!(out.status.success(), "exit: {:?}", out.status);
    assert!(String::from_utf8_lossy(&out.stdout).contains("subcommands:"));
}

#[test]
fn list_exits_successfully_and_names_figures() {
    let out = dlapm().arg("list").output().expect("spawning dlapm");
    assert!(out.status.success(), "exit: {:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("figure ids:"), "{text}");
    assert!(text.contains("fig4_12"), "{text}");
    assert!(text.contains("haswell"), "{text}");
}

#[test]
fn help_documents_gen_and_jobs() {
    let out = dlapm().arg("help").output().expect("spawning dlapm");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("gen"), "{text}");
    assert!(text.contains("--jobs"), "{text}");
    assert!(text.contains("--all"), "{text}");
    assert!(text.contains("blocksize"), "{text}");
    assert!(text.contains("--store"), "{text}");
    assert!(text.contains("--shards"), "{text}");
}

/// ISSUE 8 acceptance: output bytes never depend on the lock-shard count.
/// Full product over `--shards` {default, 1, 4} x `--jobs` {1, 4} for the
/// three cache-heavy commands, each compared byte-for-byte against the
/// flagless baseline.
#[test]
fn shard_count_never_changes_output_bytes() {
    let commands: [&[&str]; 3] = [
        &["contract", "--spec", "abc=ai,ibc", "--n", "30", "--seed", "7", "--rank"],
        &[
            "select", "--cpu", "sandybridge", "--lib", "openblas", "--op", "potrf", "--n",
            "520", "--b", "104", "--seed", "5",
        ],
        &[
            "blocksize", "--op", "potrf", "--cpu", "sandybridge", "--lib", "openblas", "--n",
            "520", "--b", "24,72,120", "--seed", "5",
        ],
    ];
    for base in commands {
        let run = |shards: Option<&str>, jobs: &str| {
            let mut args: Vec<&str> = base.to_vec();
            args.extend_from_slice(&["--jobs", jobs]);
            if let Some(s) = shards {
                args.extend_from_slice(&["--shards", s]);
            }
            let out = dlapm().args(&args).output().expect("spawning dlapm");
            assert!(out.status.success(), "{args:?}: {:?}", out.status);
            out.stdout
        };
        let baseline = run(None, "1");
        assert!(!baseline.is_empty(), "{base:?} printed nothing");
        for jobs in ["1", "4"] {
            for shards in [None, Some("1"), Some("4")] {
                if shards.is_none() && jobs == "1" {
                    continue; // that's the baseline itself
                }
                let got = run(shards, jobs);
                assert_eq!(
                    String::from_utf8_lossy(&baseline),
                    String::from_utf8_lossy(&got),
                    "{base:?} with --shards {shards:?} --jobs {jobs} changed output bytes"
                );
            }
        }
    }
}

/// Acceptance criterion of ISSUE 3: `contract --rank` stdout is
/// byte-identical for any `--jobs` value, and the reported total
/// micro-benchmark cost stays strictly below the predicted runtime of
/// the fastest-ranked algorithm on the paper's running example.
#[test]
fn contract_rank_jobs_parity_and_micro_cost_headline() {
    let rank = |jobs: &str| {
        let out = dlapm()
            .args([
                "contract", "--spec", "abc=ai,ibc", "--n", "96", "--seed", "7", "--rank",
                "--jobs", jobs,
            ])
            .output()
            .expect("spawning dlapm contract");
        assert!(out.status.success(), "contract --jobs {jobs}: {:?}", out.status);
        out.stdout
    };
    let a = rank("1");
    let b = rank("4");
    assert!(!a.is_empty());
    assert_eq!(
        String::from_utf8_lossy(&a),
        String::from_utf8_lossy(&b),
        "contract --rank must print identical rankings for --jobs 1 and --jobs 4"
    );

    let text = String::from_utf8_lossy(&a);
    assert!(text.contains("total micro-benchmark cost"), "{text}");
    // Parse "... = F x fastest predicted ..." and check F < 1 (the
    // §6.3.4 efficiency headline, enforced end-to-end).
    let frac_line = text
        .lines()
        .find(|l| l.contains("x fastest predicted"))
        .unwrap_or_else(|| panic!("no micro-cost ratio line in:\n{text}"));
    let frac: f64 = frac_line
        .rsplit('=')
        .next()
        .and_then(|rhs| rhs.trim().split_whitespace().next())
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("unparsable ratio line: {frac_line}"));
    assert!(
        frac > 0.0 && frac < 1.0,
        "total micro cost must be a strict fraction of the fastest predicted runtime: {frac_line}"
    );
}

/// Sweep mode: multiple `--n` sizes share one memo; each size reports
/// its ranking, the cumulative footer appears once, and `--csv` records
/// one per-size block per ranking.
#[test]
fn contract_sweep_ranks_every_size() {
    let csv_path = std::env::temp_dir().join(format!("dlapm_sweep_{}.csv", std::process::id()));
    let out = dlapm()
        .args([
            "contract", "--spec", "abc=ai,ibc", "--sweep", "24,32", "--seed", "7", "--jobs", "2",
            "--csv",
        ])
        .arg(&csv_path)
        .output()
        .expect("spawning dlapm contract --sweep");
    assert!(out.status.success(), "{:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("with n=24"), "{text}");
    assert!(text.contains("with n=32"), "{text}");
    assert_eq!(text.matches("total micro-benchmark cost").count(), 1, "{text}");
    let csv = std::fs::read_to_string(&csv_path).expect("--csv file written");
    let _ = std::fs::remove_file(&csv_path);
    assert!(csv.starts_with("# n=24\nrank,name,"), "{csv}");
    assert!(csv.contains("# n=32\n"), "{csv}");
}

/// ISSUE 4: the default memo granularity (1 = exact keys) must be
/// byte-identical to not passing the flag at all — the CI smoke stage's
/// contract, enforced here end-to-end.
#[test]
fn contract_memo_granularity_one_matches_default_byte_for_byte() {
    let run = |extra: &[&str]| {
        let mut args = vec![
            "contract", "--spec", "abc=ai,ibc", "--sweep", "24,32", "--seed", "7", "--jobs", "2",
        ];
        args.extend_from_slice(extra);
        let out = dlapm().args(&args).output().expect("spawning dlapm contract");
        assert!(out.status.success(), "{:?}", out.status);
        out.stdout
    };
    let default = run(&[]);
    let explicit = run(&["--memo-granularity", "1"]);
    assert!(!default.is_empty());
    assert_eq!(
        String::from_utf8_lossy(&default),
        String::from_utf8_lossy(&explicit),
        "--memo-granularity 1 must be bit-identical to the default"
    );
}

/// ISSUE 4: a coarse memo granularity turns a sweep's second size into
/// cross-size memo reuse (n=30 and n=32 quantize together at g=8), the
/// selection-quality delta vs exact keys is printed, and stdout stays
/// byte-identical for any `--jobs` value.
#[test]
fn contract_sweep_coarse_granularity_reuses_across_sizes() {
    let run = |jobs: &str| {
        let out = dlapm()
            .args([
                "contract", "--spec", "abc=ai,ibc", "--sweep", "30,32", "--seed", "7",
                "--memo-granularity", "8", "--jobs", jobs,
            ])
            .output()
            .expect("spawning dlapm contract");
        assert!(out.status.success(), "{:?}", out.status);
        out.stdout
    };
    let a = run("1");
    let b = run("4");
    assert_eq!(
        String::from_utf8_lossy(&a),
        String::from_utf8_lossy(&b),
        "granularity > 1 must stay byte-identical across job counts"
    );
    let text = String::from_utf8_lossy(&a);
    // First size: nothing to reuse yet. Second size: full reuse.
    let reuse_of = |n: usize| -> (usize, usize) {
        let line = text
            .lines()
            .find(|l| l.contains(&format!("memo reuse for n={n}:")))
            .unwrap_or_else(|| panic!("no reuse line for n={n} in:\n{text}"));
        let rest = line.split(':').nth(1).expect("colon");
        let mut words = rest.split_whitespace();
        let reused = words.next().unwrap().parse().unwrap();
        assert_eq!(words.next(), Some("of"));
        let total = words.next().unwrap().parse().unwrap();
        (reused, total)
    };
    let (r30, t30) = reuse_of(30);
    assert_eq!(r30, 0, "first sweep size cannot reuse");
    let (r32, t32) = reuse_of(32);
    assert!(r32 > 0, "cross-size reuse expected at n=32: {text}");
    assert_eq!((r32, t32), (t30, t30), "n=32 must reuse every n=30 benchmark");
    assert!(
        text.contains("selection-quality delta vs exact keys (granularity 8)"),
        "{text}"
    );
}

/// ISSUE 4: the §6.3.2/§6.3.3 scenario presets run through the unified
/// ranking (they imply --rank).
#[test]
fn contract_presets_rank_through_the_core() {
    for (preset, spec) in [("vector", "a=iaj,ji"), ("challenging", "abc=ija,jbic")] {
        let out = dlapm()
            .args(["contract", "--preset", preset, "--n", "24", "--seed", "7", "--jobs", "2"])
            .output()
            .expect("spawning dlapm contract --preset");
        assert!(out.status.success(), "--preset {preset}: {:?}", out.status);
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains(&format!("algorithms for {spec} with n=24")), "{text}");
        assert!(text.contains("total micro-benchmark cost"), "{text}");
    }
    let bad = dlapm()
        .args(["contract", "--preset", "nonsense"])
        .output()
        .expect("spawning dlapm contract --preset nonsense");
    assert!(!bad.status.success(), "unknown preset must fail");
    // A preset sets the spec; passing both is a conflict, not a silent
    // override of whichever the user thought would win.
    let conflict = dlapm()
        .args(["contract", "--preset", "vector", "--spec", "abc=ai,ibc"])
        .output()
        .expect("spawning dlapm contract --preset+--spec");
    assert!(!conflict.status.success(), "--preset with --spec must fail");
}

/// ISSUE 4: `select --validate` fans its measurement repetitions out as
/// nested engine jobs — stdout must stay byte-identical for any --jobs.
#[test]
fn select_validate_jobs_parity_byte_for_byte() {
    let run = |jobs: &str| {
        let out = dlapm()
            .args([
                "select", "--cpu", "sandybridge", "--lib", "openblas", "--op", "potrf", "--n",
                "520", "--b", "104", "--validate", "--reps", "2", "--seed", "5", "--jobs", jobs,
            ])
            .output()
            .expect("spawning dlapm select");
        assert!(out.status.success(), "select --jobs {jobs}: {:?}", out.status);
        out.stdout
    };
    let a = run("1");
    let b = run("4");
    let text = String::from_utf8_lossy(&a);
    assert!(text.contains("selection quality"), "{text}");
    assert_eq!(
        text,
        String::from_utf8_lossy(&b),
        "select --validate must print identical rankings for --jobs 1 and --jobs 4"
    );
}

/// ISSUE 5 acceptance: the second `contract --sweep 30,32 --store DIR`
/// run loads the warm micro-benchmark memo, reports zero new
/// micro-benchmarks for the previously-seen keys, and prints
/// byte-identical ranking output to the first (cold) run.
#[test]
fn contract_store_warm_restart_is_byte_identical_and_pays_zero() {
    let dir = TempDir::new("warm_contract");
    let run = || {
        let out = dlapm()
            .args([
                "contract", "--spec", "abc=ai,ibc", "--sweep", "30,32", "--seed", "7", "--jobs",
                "2", "--store",
            ])
            .arg(&dir.0)
            .output()
            .expect("spawning dlapm contract --store");
        assert!(out.status.success(), "{:?}", out.status);
        out.stdout
    };
    let cold = run();
    let warm = run();
    let cold_text = String::from_utf8_lossy(&cold).to_string();
    let warm_text = String::from_utf8_lossy(&warm).to_string();
    assert!(cold_text.contains("cold start (no snapshot)"), "{cold_text}");
    assert!(warm_text.contains("micro_memo_g1.v1.g1.s7: loaded"), "{warm_text}");
    // Zero new micro-benchmarks anywhere in the warm run.
    for n in [30, 32] {
        let zero_line = format!("micro-benchmarks for n={n}: 0.000000 ms over 0 kernel runs");
        assert!(
            warm_text.contains(&zero_line),
            "warm run must pay zero for n={n}:\n{warm_text}"
        );
        // Every distinct benchmark key is a cross-run reuse.
        let reuse = warm_text
            .lines()
            .find(|l| l.contains(&format!("memo reuse for n={n}:")))
            .unwrap_or_else(|| panic!("no reuse line for n={n}:\n{warm_text}"));
        let mut words = reuse.split(':').nth(1).expect("colon").split_whitespace();
        let reused: usize = words.next().unwrap().parse().unwrap();
        assert_eq!(words.next(), Some("of"));
        let total: usize = words.next().unwrap().parse().unwrap();
        assert_eq!(reused, total, "full warm reuse expected: {reuse}");
    }
    assert!(
        warm_text.contains("total micro-benchmark cost: 0.000000 ms over 0 kernel runs"),
        "{warm_text}"
    );
    // The ranking output itself is byte-identical cold vs warm.
    let (cold_rows, warm_rows) = (ranking_rows(&cold), ranking_rows(&warm));
    assert!(!cold_rows.is_empty(), "{cold_text}");
    assert_eq!(cold_rows, warm_rows, "cold and warm rankings must match byte for byte");
}

/// A different seed never loads foreign measurements: it starts cold in
/// its own seed-keyed snapshot — and leaves the original seed's warm
/// state intact (differently-keyed snapshots coexist, not clobber).
#[test]
fn contract_store_mismatched_seed_starts_cold_and_preserves_prior_state() {
    let dir = TempDir::new("warm_mismatch");
    let run = |seed: &str| {
        let out = dlapm()
            .args([
                "contract", "--spec", "abc=ai,ibc", "--n", "30", "--seed", seed, "--jobs", "2",
                "--store",
            ])
            .arg(&dir.0)
            .output()
            .expect("spawning dlapm contract --store");
        assert!(out.status.success(), "seed {seed}: {:?}", out.status);
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    let first = run("7");
    assert!(first.contains("cold start (no snapshot)"), "{first}");
    let second = run("8");
    assert!(
        second.contains("micro_memo_g1.v1.g1.s8: cold start (no snapshot)"),
        "a different seed must start cold in its own snapshot:\n{second}"
    );
    // Both seeds now have warm state; neither run destroyed the other's.
    let third = run("7");
    assert!(third.contains("micro_memo_g1.v1.g1.s7: loaded"), "{third}");
    let fourth = run("8");
    assert!(fourth.contains("micro_memo_g1.v1.g1.s8: loaded"), "{fourth}");
}

/// A corrupt snapshot is loud: the run fails with the offending path in
/// the error instead of silently recomputing over damaged state.
#[test]
fn contract_store_corrupt_snapshot_fails_with_path() {
    let dir = TempDir::new("warm_corrupt");
    // Default contract machine is haswell/openblas/1t; seed 7 and the
    // default granularity 1 name the snapshot file.
    let machine_dir = dir.0.join("haswell_openblas_1t");
    std::fs::create_dir_all(&machine_dir).unwrap();
    std::fs::write(machine_dir.join("micro_memo_g1.v1.g1.s7.json"), "{ definitely not json")
        .unwrap();
    let out = dlapm()
        .args(["contract", "--spec", "abc=ai,ibc", "--n", "30", "--seed", "7", "--store"])
        .arg(&dir.0)
        .output()
        .expect("spawning dlapm contract --store");
    assert!(!out.status.success(), "corrupt snapshot must fail the run");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("micro_memo_g1.v1.g1.s7.json"), "{err}");
    assert!(err.contains("corrupt warm snapshot"), "{err}");
}

/// The new §4.6 CLI surface: `blocksize` ranks candidate block sizes
/// through the selection core, emits the yield table under --validate,
/// and restarts warm (models + estimate cache) from a --store directory.
#[test]
fn blocksize_cli_ranks_validates_and_warm_restarts() {
    let dir = TempDir::new("warm_blocksize");
    let run = || {
        let out = dlapm()
            .args([
                "blocksize", "--op", "potrf", "--cpu", "sandybridge", "--lib", "openblas", "--n",
                "520", "--b", "24,72,120,168", "--validate", "--reps", "2", "--seed", "5",
                "--jobs", "2", "--store",
            ])
            .arg(&dir.0)
            .output()
            .expect("spawning dlapm blocksize");
        assert!(out.status.success(), "{:?}", out.status);
        out
    };
    let cold = run();
    let cold_text = String::from_utf8_lossy(&cold.stdout).to_string();
    assert!(cold_text.contains("block-size ranking for dpotrf"), "{cold_text}");
    assert!(cold_text.contains("predicted optimal block size for n=520: b="), "{cold_text}");
    assert!(cold_text.contains("block-size yield"), "{cold_text}");
    assert!(cold_text.contains("b_pred"), "{cold_text}");
    assert!(cold_text.contains("cold start (no snapshot)"), "{cold_text}");
    let warm = run();
    let warm_text = String::from_utf8_lossy(&warm.stdout).to_string();
    assert!(warm_text.contains(": loaded"), "{warm_text}");
    // Modulo the warm-store status lines, the two runs print the same
    // bytes: rankings, b_pred and yields are all reloaded-state pure.
    let strip = |text: &str| -> Vec<String> {
        text.lines().filter(|l| !l.starts_with("warm store:")).map(|l| l.to_string()).collect()
    };
    assert_eq!(strip(&cold_text), strip(&warm_text));
}

/// `select` over an (n, b) grid: one ranking per grid point, all served
/// by one prewarmed estimate cache.
#[test]
fn select_grid_ranks_every_pair() {
    let out = dlapm()
        .args([
            "select", "--cpu", "sandybridge", "--lib", "openblas", "--op", "potrf", "--n", "520",
            "--b", "104,112", "--seed", "5", "--jobs", "2",
        ])
        .output()
        .expect("spawning dlapm select grid");
    assert!(out.status.success(), "{:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("predicted ranking for n=520, b=104"), "{text}");
    assert!(text.contains("predicted ranking for n=520, b=112"), "{text}");
}

/// End-to-end `--jobs` parity through the real binary: `gen --jobs 1`
/// and `gen --jobs 4` write byte-identical model stores.
#[test]
fn gen_jobs_parity_byte_for_byte() {
    let dir = std::env::temp_dir()
        .join(format!("dlapm_cli_gen_{}", dlapm::util::sync::unique_token()));
    std::fs::create_dir_all(&dir).unwrap();
    struct Cleanup(std::path::PathBuf);
    impl Drop for Cleanup {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }
    let _cleanup = Cleanup(dir.clone());

    let gen = |jobs: &str, file: &str| {
        let path = dir.join(file);
        let out = dlapm()
            .args([
                "gen", "--op", "potrf", "--cpu", "sandybridge", "--lib", "openblas",
                "--max-n", "536", "--max-b", "104", "--seed", "5", "--jobs", jobs, "--out",
            ])
            .arg(&path)
            .output()
            .expect("spawning dlapm gen");
        assert!(out.status.success(), "gen --jobs {jobs}: {:?}", out.status);
        std::fs::read(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
    };
    let a = gen("1", "jobs1.json");
    let b = gen("4", "jobs4.json");
    assert!(!a.is_empty());
    assert_eq!(a, b, "gen --jobs 1 and --jobs 4 must write identical stores");
}

/// `dlapm lint` exits 0 on the crate's own (post-fix) source tree and
/// prints the clean summary.
#[test]
fn lint_self_scan_is_clean() {
    // cargo test runs with CWD = the crate root, so `src` resolves.
    let out = dlapm().arg("lint").output().expect("spawning dlapm lint");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "dlapm lint flagged the tree:\n{text}");
    assert!(text.contains("clean"), "{text}");
}

/// `dlapm lint --src DIR` exits non-zero on a tree with a violation and
/// reports it as `file:line rule message`.
#[test]
fn lint_reports_violations_with_nonzero_exit() {
    let dir = TempDir::new("cli_lint");
    std::fs::write(
        dir.path().join("bad.rs"),
        "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n",
    )
    .unwrap();
    let out = dlapm()
        .args(["lint", "--src"])
        .arg(dir.path())
        .output()
        .expect("spawning dlapm lint --src");
    assert_eq!(out.status.code(), Some(1), "{:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("bad.rs:2 nan-partial-cmp"), "{text}");
    assert!(text.contains("1 violation(s)"), "{text}");
}
