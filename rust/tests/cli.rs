//! CLI smoke tests: exercise the `dlapm` binary end-to-end so `main.rs`
//! is covered by `cargo test`.

use std::process::Command;

fn dlapm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dlapm"))
}

#[test]
fn help_exits_successfully() {
    let out = dlapm().arg("help").output().expect("spawning dlapm");
    assert!(out.status.success(), "exit: {:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("subcommands:"), "{text}");
    assert!(text.contains("figures"), "{text}");
}

#[test]
fn no_arguments_prints_help() {
    let out = dlapm().output().expect("spawning dlapm");
    assert!(out.status.success(), "exit: {:?}", out.status);
    assert!(String::from_utf8_lossy(&out.stdout).contains("subcommands:"));
}

#[test]
fn list_exits_successfully_and_names_figures() {
    let out = dlapm().arg("list").output().expect("spawning dlapm");
    assert!(out.status.success(), "exit: {:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("figure ids:"), "{text}");
    assert!(text.contains("fig4_12"), "{text}");
    assert!(text.contains("haswell"), "{text}");
}
