//! End-to-end tests for `dlapm serve`: the stdio batch transport, the
//! TCP transport with its `--client` one-shot, `--jobs` parity, warm
//! restart from a `--store` directory, and the structured-error contract
//! of the wire protocol (docs/serve-protocol.md).

use std::io::{BufRead, BufReader, Write};
use std::process::{Command, Stdio};

use dlapm::util::json::Json;

mod common;
use common::TempDir;

fn dlapm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dlapm"))
}

/// Run `dlapm serve --stdio` with `extra` args, feed `script` on stdin
/// (EOF after the last line), return (stdout, stderr, exit-success).
fn serve_stdio(extra: &[&str], script: &str) -> (String, String, bool) {
    let mut child = dlapm()
        .args(["serve", "--stdio"])
        .args(extra)
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawning dlapm serve --stdio");
    child
        .stdin
        .take()
        .expect("piped stdin")
        .write_all(script.as_bytes())
        .expect("writing request script");
    // stdin dropped above: the daemon sees EOF after the script and runs
    // its graceful-shutdown path (final checkpoint) on its own.
    let out = child.wait_with_output().expect("waiting for dlapm serve");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

const SELECT: &str =
    r#"{"op":"select","cpu":"sandybridge","family":"potrf","n":520,"b":104,"seed":5,"id":1}"#;
const CONTRACT: &str =
    r#"{"op":"contract_rank","spec":"abc=ai,ibc","n":20,"small":4,"seed":7,"id":2}"#;
const STATUS: &str = r#"{"op":"status","id":3}"#;

/// The tentpole contract: the `output` field of a serve response is
/// byte-identical to what the equivalent CLI invocation prints.
#[test]
fn select_response_output_equals_cli_stdout() {
    let (stdout, stderr, ok) = serve_stdio(&["--jobs", "2"], &format!("{SELECT}\n"));
    assert!(ok, "{stderr}");
    let resp = Json::parse(stdout.lines().next().expect("one response line")).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{stdout}");
    assert_eq!(resp.get("op").unwrap().as_str(), Some("select"));
    let served = resp.get("output").unwrap().as_str().unwrap().to_string();
    let cli = dlapm()
        .args([
            "select", "--cpu", "sandybridge", "--lib", "openblas", "--op", "potrf", "--n",
            "520", "--b", "104", "--seed", "5", "--jobs", "2",
        ])
        .output()
        .expect("spawning dlapm select");
    assert!(cli.status.success(), "{:?}", cli.status);
    assert_eq!(
        served,
        String::from_utf8_lossy(&cli.stdout),
        "serve 'output' must be byte-identical to the CLI's stdout"
    );
}

/// Whole-batch determinism: the same request script answered at
/// `--jobs 1` and `--jobs 4` produces byte-identical stdout, and an
/// identical request repeated within one batch gets identical bytes.
#[test]
fn stdio_batch_is_byte_identical_across_jobs_and_repeats() {
    let script = format!(
        "{CONTRACT}\n\
         {{\"op\":\"blocksize\",\"family\":\"potrf\",\"cpu\":\"sandybridge\",\"n\":520,\
         \"bs\":[24,72,120],\"seed\":5,\"id\":2}}\n\
         {CONTRACT}\n\
         {STATUS}\n\
         {{\"op\":\"shutdown\",\"id\":4}}\n"
    );
    let (a, err_a, ok_a) = serve_stdio(&["--jobs", "1"], &script);
    let (b, err_b, ok_b) = serve_stdio(&["--jobs", "4"], &script);
    assert!(ok_a, "{err_a}");
    assert!(ok_b, "{err_b}");
    assert_eq!(a, b, "serve --jobs 1 and --jobs 4 must answer byte-identically");
    let lines: Vec<&str> = a.lines().collect();
    assert_eq!(lines.len(), 5, "{a}");
    assert_eq!(lines[0], lines[2], "identical requests must get identical response bytes");
    let bye = Json::parse(lines[4]).unwrap();
    assert_eq!(bye.get("ok").unwrap().as_bool(), Some(true), "{}", lines[4]);
}

/// The zero-marginal-cost acceptance criterion: a second identical
/// request generates no models and runs no new micro-benchmarks — the
/// `status` counters before and after prove it.
#[test]
fn second_identical_request_does_zero_new_work() {
    let script = format!("{SELECT}\n{CONTRACT}\n{STATUS}\n{SELECT}\n{CONTRACT}\n{STATUS}\n");
    let (out, err, ok) = serve_stdio(&["--jobs", "2"], &script);
    assert!(ok, "{err}");
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 6, "{out}");
    assert_eq!(lines[0], lines[3], "repeat select must be byte-identical");
    assert_eq!(lines[1], lines[4], "repeat contract_rank must be byte-identical");
    let counters = |line: &str| {
        let d = Json::parse(line).unwrap();
        let d = d.get("data").cloned().unwrap();
        (
            d.get("models_generated").unwrap().as_usize().unwrap(),
            d.get("memo_kernel_runs").unwrap().as_usize().unwrap(),
            d.get("models").unwrap().as_usize().unwrap(),
            d.get("model_cache_entries").unwrap().as_usize().unwrap(),
        )
    };
    let first = counters(lines[2]);
    let second = counters(lines[5]);
    assert!(first.0 > 0, "cold select must generate models: {}", lines[2]);
    assert!(first.1 > 0, "cold contract_rank must micro-benchmark: {}", lines[2]);
    assert_eq!(
        second, first,
        "repeated requests must add zero models, zero kernel runs, zero cache entries"
    );
}

/// Bad input never kills the daemon: each malformed / unknown / invalid
/// request gets a structured error object and the process still exits 0.
#[test]
fn malformed_and_unknown_requests_error_structurally_with_exit_zero() {
    let script = concat!(
        "this is not json\n",
        r#"{"op":"florble","id":1}"#,
        "\n",
        r#"{"op":"status","id":2,"surprise":true}"#,
        "\n",
        r#"{"op":"predict","v":2,"id":3}"#,
        "\n",
        "\n", // blank keep-alive line: no response at all
        r#"{"op":"status","id":4}"#,
        "\n",
    );
    let (out, err, ok) = serve_stdio(&[], script);
    assert!(ok, "bad requests must not kill the daemon: {err}");
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 5, "blank lines get no response: {out}");
    let code = |line: &str| {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "{line}");
        j.get("error").unwrap().get("code").unwrap().as_str().unwrap().to_string()
    };
    assert_eq!(code(lines[0]), "parse");
    assert_eq!(code(lines[1]), "unknown-op");
    assert_eq!(code(lines[2]), "bad-request"); // unknown field for status
    assert_eq!(code(lines[3]), "version");
    let last = Json::parse(lines[4]).unwrap();
    assert_eq!(last.get("ok").unwrap().as_bool(), Some(true), "{}", lines[4]);
    assert_eq!(last.get("id").unwrap().as_usize(), Some(4));
}

/// Warm restart: a daemon shut down over a `--store` directory
/// checkpoints its state; a second daemon over the same directory
/// answers byte-identically while generating nothing new.
#[test]
fn warm_restart_from_store_is_byte_identical_and_regenerates_nothing() {
    let dir = TempDir::new("serve_store");
    let store = dir.path().to_str().expect("utf-8 temp path").to_string();
    let script = format!("{SELECT}\n{CONTRACT}\n{STATUS}\n{{\"op\":\"shutdown\"}}\n");
    let run = || serve_stdio(&["--jobs", "2", "--store", &store], &script);
    let (cold, cold_err, ok_cold) = run();
    assert!(ok_cold, "{cold_err}");
    assert!(
        cold_err.contains("cold start (no snapshot)"),
        "first run must start cold:\n{cold_err}"
    );
    let (warm, warm_err, ok_warm) = run();
    assert!(ok_warm, "{warm_err}");
    assert!(warm_err.contains(": loaded"), "second run must warm-load:\n{warm_err}");
    // Nothing grew in the warm run, so its final checkpoint writes nothing.
    assert!(
        warm_err.contains("event=shutdown 0 warm slot(s) checkpointed"),
        "{warm_err}"
    );
    let (cold_lines, warm_lines): (Vec<&str>, Vec<&str>) =
        (cold.lines().collect(), warm.lines().collect());
    assert_eq!(cold_lines.len(), 4, "{cold}");
    assert_eq!(warm_lines.len(), 4, "{warm}");
    // The prediction responses (not the state-dependent status) are
    // byte-identical cold vs warm.
    assert_eq!(cold_lines[0], warm_lines[0]);
    assert_eq!(cold_lines[1], warm_lines[1]);
    let warm_status = Json::parse(warm_lines[2]).unwrap();
    let data = warm_status.get("data").cloned().unwrap();
    assert_eq!(
        data.get("models_generated").unwrap().as_usize(),
        Some(0),
        "warm daemon must regenerate nothing: {}",
        warm_lines[2]
    );
    assert!(data.get("models").unwrap().as_usize().unwrap() > 0);
    assert_eq!(data.get("store").unwrap().as_bool(), Some(true));
}

/// Spawn a TCP daemon with `extra` args, parse the announced address off
/// stderr, and leave a drain thread running so the daemon can never block
/// on a full stderr pipe. Returns (child, addr).
fn spawn_tcp(extra: &[&str]) -> (std::process::Child, String) {
    let mut child = dlapm()
        .args(["serve", "--addr", "127.0.0.1:0"])
        .args(extra)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawning dlapm serve --addr");
    let mut reader = BufReader::new(child.stderr.take().expect("piped stderr"));
    let mut addr = None;
    let mut line = String::new();
    while reader.read_line(&mut line).unwrap_or(0) > 0 {
        if let Some(rest) = line.trim().strip_prefix("[dlapm serve] level=info event=listening ") {
            addr = Some(rest.to_string());
            break;
        }
        line.clear();
    }
    let addr = addr.expect("daemon never announced a listening address");
    std::thread::spawn(move || {
        let mut sink = String::new();
        loop {
            sink.clear();
            match reader.read_line(&mut sink) {
                Ok(0) | Err(_) => return,
                Ok(_) => {}
            }
        }
    });
    (child, addr)
}

/// One-shot `--client` round trip against `addr`; asserts exit 0 (the
/// client exits 0 even for structured error responses) and returns the
/// trimmed response line.
fn one_shot(addr: &str, req: &str) -> String {
    let out = dlapm()
        .args(["serve", "--client", req, "--addr", addr])
        .output()
        .expect("spawning dlapm serve --client");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    String::from_utf8_lossy(&out.stdout).trim().to_string()
}

/// TCP transport: the daemon announces its bound address on stderr, the
/// `--client` one-shot round-trips a request, and a shutdown request
/// terminates the daemon with exit 0.
#[test]
fn tcp_client_one_shot_round_trip_and_shutdown() {
    let (mut child, addr) = spawn_tcp(&["--jobs", "2"]);
    let client = |req: &str| one_shot(&addr, req);
    let resp =
        client(r#"{"op":"predict","cpu":"sandybridge","n":520,"b":104,"seed":5,"id":"p1"}"#);
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    assert_eq!(j.get("id").unwrap().as_str(), Some("p1"));
    assert!(j.get("output").unwrap().as_str().unwrap().contains("t_med="), "{resp}");
    let bye = client(r#"{"op":"shutdown"}"#);
    let j = Json::parse(&bye).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{bye}");
    let status = child.wait().expect("waiting for dlapm serve");
    assert!(status.success(), "daemon exit: {status:?}");
}

/// `--client-script`: every non-blank line goes over ONE TCP connection,
/// one response line per request in order, blank lines skipped — and each
/// response is byte-identical to a one-shot `--client` of the same
/// request (responses are pure functions of the request).
#[test]
fn client_script_reuses_one_connection_and_matches_one_shots() {
    let (mut child, addr) = spawn_tcp(&["--jobs", "2"]);
    let pred = r#"{"op":"predict","cpu":"sandybridge","n":520,"b":104,"seed":5,"id":"p1"}"#;
    let dir = TempDir::new("serve_client_script");
    let script_path = dir.path().join("script.jsonl");
    // Blank line in the middle: keep-alive, must produce no response line.
    std::fs::write(&script_path, format!("{pred}\n\n{pred}\n")).expect("writing script");
    let out = dlapm()
        .args(["serve", "--client-script"])
        .arg(&script_path)
        .args(["--addr", &addr])
        .output()
        .expect("spawning dlapm serve --client-script");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    let lines: Vec<&str> = stdout.lines().collect();
    assert_eq!(lines.len(), 2, "two requests, two responses: {stdout}");
    assert_eq!(lines[0], lines[1], "identical requests must answer byte-identically");
    let j = Json::parse(lines[0]).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{}", lines[0]);
    assert_eq!(j.get("id").unwrap().as_str(), Some("p1"));
    // The persistent connection answers exactly like the one-shot client.
    assert_eq!(lines[0], one_shot(&addr, pred));
    let bye = one_shot(&addr, r#"{"op":"shutdown"}"#);
    assert_eq!(Json::parse(&bye).unwrap().get("ok").unwrap().as_bool(), Some(true), "{bye}");
    let status = child.wait().expect("waiting for dlapm serve");
    assert!(status.success(), "daemon exit: {status:?}");
}

/// The batching purity rule, end to end: the same stdio script answered
/// with batching off, with a 4-arrival window, and with the window
/// degenerated by `--batch-max 1`, crossed with `--jobs` 1/4 and
/// `--shards` 1/4, produces byte-identical stdout in all twelve
/// configurations. Three same-scope selects (they fuse into one class at
/// window 4) plus a predict (its own class — op kind splits scopes).
#[test]
fn batching_is_byte_identical_across_windows_jobs_and_shards() {
    let script = concat!(
        r#"{"op":"select","cpu":"sandybridge","n":520,"b":104,"seed":5,"id":"s1"}"#,
        "\n",
        r#"{"op":"select","cpu":"sandybridge","n":400,"b":96,"seed":5,"id":"s2"}"#,
        "\n",
        r#"{"op":"select","cpu":"sandybridge","n":360,"b":104,"seed":5,"id":"s3"}"#,
        "\n",
        r#"{"op":"predict","cpu":"sandybridge","n":520,"b":104,"seed":5,"id":"p1"}"#,
        "\n",
    );
    // One shared warm store: the first run generates the models, the rest
    // warm-load — response purity makes cold and warm bytes identical,
    // and the sharing keeps twelve daemon runs cheap.
    let dir = TempDir::new("serve_batch_parity");
    let store = dir.path().to_str().expect("utf-8 temp path").to_string();
    let batch_cfgs: [&[&str]; 3] = [
        &[],
        &["--batch-window", "4"],
        &["--batch-window", "4", "--batch-max", "1"],
    ];
    let mut baseline: Option<String> = None;
    for jobs in ["1", "4"] {
        for shards in ["1", "4"] {
            for batch in batch_cfgs {
                let mut extra = vec!["--jobs", jobs, "--shards", shards, "--store", &store];
                extra.extend_from_slice(batch);
                let (out, err, ok) = serve_stdio(&extra, script);
                assert!(ok, "jobs {jobs} shards {shards} {batch:?}: {err}");
                assert_eq!(out.lines().count(), 4, "{out}");
                match &baseline {
                    None => baseline = Some(out),
                    Some(first) => assert_eq!(
                        &out, first,
                        "jobs {jobs} shards {shards} {batch:?} changed response bytes"
                    ),
                }
            }
        }
    }
}

/// The fused-execution acceptance criterion, observable over the wire:
/// three same-scope selects inside one window report exactly one fused
/// class of three requests, one fused engine fan-out, zero per-request
/// fan-outs, and a positive batched-point count.
#[test]
fn fused_class_counters_show_one_fanout_and_batched_points() {
    let script = concat!(
        r#"{"op":"select","cpu":"sandybridge","n":520,"b":104,"seed":5,"id":"s1"}"#,
        "\n",
        r#"{"op":"select","cpu":"sandybridge","n":400,"b":96,"seed":5,"id":"s2"}"#,
        "\n",
        r#"{"op":"select","cpu":"sandybridge","n":360,"b":104,"seed":5,"id":"s3"}"#,
        "\n",
        r#"{"op":"status","id":"st"}"#,
        "\n",
    );
    let (out, err, ok) = serve_stdio(&["--jobs", "2", "--batch-window", "8"], script);
    assert!(ok, "{err}");
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 4, "{out}");
    for line in &lines[..3] {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{line}");
    }
    let status = Json::parse(lines[3]).unwrap();
    let data = status.get("data").cloned().unwrap();
    let count = |k: &str| data.get(k).unwrap().as_usize().unwrap();
    assert_eq!(count("batch_classes"), 1, "{}", lines[3]);
    assert_eq!(count("batch_requests_fused"), 3, "{}", lines[3]);
    assert_eq!(count("batch_fanouts"), 1, "one engine fan-out for the class: {}", lines[3]);
    assert_eq!(count("single_fanouts"), 0, "no per-request fan-outs: {}", lines[3]);
    assert!(count("batch_points_fused") > 0, "points must batch-evaluate: {}", lines[3]);
    assert!(count("queue_peak") >= 1, "{}", lines[3]);
}

/// The tracing purity rule, end to end: for every combination of
/// `--jobs` 1/4, `--shards` 1/4 and `--batch-window` 0/3, the same stdio
/// script answered with `--trace FILE` produces stdout byte-identical to
/// the untraced run of the same configuration — spans only ever go to
/// the trace sink. The windowed runs' trace files must contain the full
/// request lifecycle (admit, park, class-close, fused-exec, render); the
/// unbatched runs admit and render without parking. Every trace line is
/// parseable JSON carrying the identity part (name) and the wall part
/// (seq).
#[test]
fn trace_parity_matrix_and_span_lifecycle() {
    let script = concat!(
        r#"{"op":"select","cpu":"sandybridge","n":520,"b":104,"seed":5,"id":"s1"}"#,
        "\n",
        r#"{"op":"select","cpu":"sandybridge","n":400,"b":96,"seed":5,"id":"s2"}"#,
        "\n",
        r#"{"op":"select","cpu":"sandybridge","n":360,"b":104,"seed":5,"id":"s3"}"#,
        "\n",
        r#"{"op":"status","id":"st"}"#,
        "\n",
    );
    let dir = TempDir::new("serve_trace_parity");
    let store = dir.path().join("store");
    let store = store.to_str().expect("utf-8 temp path").to_string();
    for jobs in ["1", "4"] {
        for shards in ["1", "4"] {
            for window in ["0", "3"] {
                let mut extra = vec!["--jobs", jobs, "--shards", shards, "--store", &store];
                if window != "0" {
                    extra.extend_from_slice(&["--batch-window", window]);
                }
                let (plain, err, ok) = serve_stdio(&extra, script);
                assert!(ok, "jobs {jobs} shards {shards} window {window}: {err}");
                let trace_path = dir.path().join(format!("trace_{jobs}_{shards}_{window}.jsonl"));
                let trace_file = trace_path.to_str().expect("utf-8 trace path").to_string();
                let mut traced_extra = extra.clone();
                traced_extra.extend_from_slice(&["--trace", &trace_file]);
                let (traced, terr, tok) = serve_stdio(&traced_extra, script);
                assert!(tok, "traced jobs {jobs} shards {shards} window {window}: {terr}");
                assert_eq!(
                    plain, traced,
                    "jobs {jobs} shards {shards} window {window}: --trace changed stdout bytes"
                );
                let spans = std::fs::read_to_string(&trace_path).expect("reading trace file");
                assert!(!spans.is_empty(), "trace file must not be empty");
                for line in spans.lines() {
                    let j = Json::parse(line).expect("trace line must be JSON");
                    assert!(j.get("name").unwrap().as_str().is_some(), "{line}");
                    assert!(j.get("wall").unwrap().get("seq").is_some(), "{line}");
                }
                let expected: &[&str] = if window == "0" {
                    &["serve.admit", "serve.render"]
                } else {
                    &[
                        "serve.admit",
                        "serve.park",
                        "serve.class_close",
                        "serve.fused_exec",
                        "serve.render",
                    ]
                };
                for name in expected {
                    assert!(
                        spans.contains(&format!("\"name\":\"{name}\"")),
                        "window {window}: missing span '{name}' in trace:\n{spans}"
                    );
                }
            }
        }
    }
}

/// The `metrics` wire op: a barrier op whose `output` is the sorted-name
/// text exposition of the process metrics registry — every migrated
/// counter and gauge plus the pre-registered per-op latency histograms
/// appear even before their code paths run.
#[test]
fn metrics_op_exposes_every_migrated_series() {
    let script = format!("{SELECT}\n{CONTRACT}\n{{\"op\":\"metrics\",\"id\":\"m\"}}\n");
    let (out, err, ok) = serve_stdio(&["--jobs", "2"], &script);
    assert!(ok, "{err}");
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 3, "{out}");
    let j = Json::parse(lines[2]).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{}", lines[2]);
    assert_eq!(j.get("op").unwrap().as_str(), Some("metrics"));
    let text = j.get("output").unwrap().as_str().unwrap().to_string();
    for name in [
        "dlapm_model_cache_hits_total",
        "dlapm_model_cache_misses_total",
        "dlapm_memo_hits_total",
        "dlapm_memo_misses_total",
        "dlapm_coalesce_led_total",
        "dlapm_coalesce_coalesced_total",
        "dlapm_serve_requests_total",
        "dlapm_serve_batch_classes_total",
        "dlapm_serve_batch_requests_fused_total",
        "dlapm_serve_batch_points_fused_total",
        "dlapm_serve_batch_fanouts_total",
        "dlapm_serve_single_fanouts_total",
        "dlapm_serve_models_generated_total",
        "dlapm_serve_checkpoints_total",
        "dlapm_engine_steals_total",
        "dlapm_engine_parks_total",
        "dlapm_engine_wakes_total",
        "dlapm_engine_jobs_total",
        "dlapm_serve_inflight",
        "dlapm_serve_queue_max",
        "dlapm_serve_queue_peak",
        "dlapm_serve_connections",
        "dlapm_engine_queue_depth_peak",
    ] {
        assert!(text.contains(name), "metrics output missing {name}:\n{text}");
    }
    // Per-op latency histograms are pre-registered for every protocol op.
    for op in ["predict", "select", "blocksize", "contract_rank", "status", "metrics", "shutdown"]
    {
        assert!(
            text.contains(&format!("dlapm_serve_latency_us_bucket{{op=\"{op}\",le=\"+Inf\"}}")),
            "metrics output missing latency series for op {op}:\n{text}"
        );
    }
    assert!(text.contains("# TYPE dlapm_serve_requests_total counter"), "{text}");
    // The handled requests counted so far (select, contract_rank,
    // metrics itself) are visible in the mirrored request counter.
    assert!(text.contains("dlapm_serve_requests_total 3"), "{text}");
}

/// `--retry N` on the one-shot client: while the only `--max-connections`
/// slot is held, the client is rejected with `overloaded`; once the
/// holder disconnects (mid-backoff), a retry gets through and the final
/// answer is an ordinary ok response.
#[test]
fn client_retry_recovers_from_connection_rejection() {
    let (mut child, addr) = spawn_tcp(&["--jobs", "1", "--max-connections", "1"]);
    // Occupy the only slot and prove the connection is live.
    let mut held = std::net::TcpStream::connect(&addr).expect("first connection");
    held.write_all(b"{\"op\":\"status\",\"id\":\"hold\"}\n").expect("request on held conn");
    held.flush().expect("flush held conn");
    let mut held_reader = BufReader::new(held.try_clone().expect("clone held conn"));
    let mut resp = String::new();
    held_reader.read_line(&mut resp).expect("response on held conn");
    assert_eq!(Json::parse(resp.trim()).unwrap().get("ok").unwrap().as_bool(), Some(true));
    // Free the slot only after the client has had time to be rejected at
    // least once (its backoff schedule starts at 25ms and totals ~3s).
    let holder = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(300));
        drop(held_reader);
        drop(held);
    });
    let out = dlapm()
        .args([
            "serve",
            "--client",
            r#"{"op":"status","id":"retry"}"#,
            "--addr",
            &addr,
            "--retry",
            "8",
        ])
        .output()
        .expect("spawning dlapm serve --client --retry");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let j = Json::parse(stdout.trim()).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "retry must end in success: {stdout}");
    assert_eq!(j.get("id").unwrap().as_str(), Some("retry"));
    holder.join().expect("holder thread");
    let bye = one_shot(&addr, r#"{"op":"shutdown"}"#);
    assert_eq!(Json::parse(&bye).unwrap().get("ok").unwrap().as_bool(), Some(true), "{bye}");
    let status = child.wait().expect("waiting for dlapm serve");
    assert!(status.success(), "daemon exit: {status:?}");
}

/// `--max-connections 1`: while one connection is open, a second one gets
/// a structured `overloaded` error at the accept loop (null id — no
/// request was read); after the first closes, its slot frees and new
/// connections are served again.
#[test]
fn max_connections_rejects_excess_with_overloaded_then_recovers() {
    let (mut child, addr) = spawn_tcp(&["--jobs", "1", "--max-connections", "1"]);
    // Occupy the only slot with a raw connection and prove it is live.
    let mut held = std::net::TcpStream::connect(&addr).expect("first connection");
    held.write_all(b"{\"op\":\"status\",\"id\":\"hold\"}\n").expect("request on held conn");
    held.flush().expect("flush held conn");
    let mut held_reader = BufReader::new(held.try_clone().expect("clone held conn"));
    let mut resp = String::new();
    held_reader.read_line(&mut resp).expect("response on held conn");
    let j = Json::parse(resp.trim()).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    // Second connection: rejected at the accept loop, before any request
    // is read — so reading without sending anything yields the error line
    // (and avoids racing our own write against the server's close).
    let mut second =
        BufReader::new(std::net::TcpStream::connect(&addr).expect("second connection"));
    let mut over = String::new();
    second.read_line(&mut over).expect("reading overloaded line");
    let j = Json::parse(over.trim()).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "{over}");
    assert_eq!(j.get("error").unwrap().get("code").unwrap().as_str(), Some("overloaded"));
    assert_eq!(j.get("id").unwrap(), &Json::Null, "no request line was read");
    // Close the held connection; the daemon notices within its 100ms read
    // timeout and frees the slot — retry until a client gets through. A
    // still-rejected attempt may also die on the write/close race, so
    // anything short of an ok:true response just means "retry".
    drop(held_reader);
    drop(held);
    let try_status = || -> Option<Json> {
        let mut s = std::net::TcpStream::connect(&addr).ok()?;
        let _ = s.write_all(b"{\"op\":\"status\",\"id\":\"again\"}\n");
        let _ = s.flush();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).ok()?;
        Json::parse(line.trim()).ok()
    };
    let mut recovered = false;
    for _ in 0..100 {
        if let Some(j) = try_status() {
            if j.get("ok").and_then(|o| o.as_bool()) == Some(true) {
                recovered = true;
                break;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    assert!(recovered, "slot never freed after closing the first connection");
    let bye = one_shot(&addr, r#"{"op":"shutdown"}"#);
    assert_eq!(Json::parse(&bye).unwrap().get("ok").unwrap().as_bool(), Some(true), "{bye}");
    let status = child.wait().expect("waiting for dlapm serve");
    assert!(status.success(), "daemon exit: {status:?}");
}
