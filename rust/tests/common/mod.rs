//! Helpers shared by the integration-test binaries (`tests/*.rs`).
//! (In-crate unit tests cannot see this module; `store/warm.rs` keeps
//! its own small copy.)

use std::path::{Path, PathBuf};

/// Per-process unique scratch directory, removed on every exit path
/// (including assertion-failure unwinds) via `Drop`.
pub struct TempDir(pub PathBuf);

// Not every test binary uses every helper; that's fine.
#[allow(dead_code)]
impl TempDir {
    pub fn new(tag: &str) -> TempDir {
        // Process- and call-unique without reading the wall clock (the
        // determinism lint bans SystemTime-derived names in the crate;
        // the tests follow the same discipline).
        let dir = std::env::temp_dir()
            .join(format!("dlapm_{tag}_{}", dlapm::util::sync::unique_token()));
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}
