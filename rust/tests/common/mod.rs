//! Helpers shared by the integration-test binaries (`tests/*.rs`).
//! (In-crate unit tests cannot see this module; `store/warm.rs` keeps
//! its own small copy.)

use std::path::{Path, PathBuf};

/// Per-process unique scratch directory, removed on every exit path
/// (including assertion-failure unwinds) via `Drop`.
pub struct TempDir(pub PathBuf);

// Not every test binary uses every helper; that's fine.
#[allow(dead_code)]
impl TempDir {
    pub fn new(tag: &str) -> TempDir {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let dir = std::env::temp_dir()
            .join(format!("dlapm_{tag}_{}_{nanos}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}
