//! Cross-module integration tests: the full generate -> store -> load ->
//! predict -> validate pipeline, engine parity, plus the PJRT artifact
//! path.

use std::sync::Arc;

use dlapm::engine::{Engine, ModelCache};
use dlapm::machine::{CpuId, Elem, Library, Machine};
use dlapm::modeling::ModelStore;
use dlapm::predict::algorithms::potrf::Potrf;
use dlapm::predict::algorithms::BlockedAlg;
use dlapm::predict::measurement::{coverage, measure_algorithm};
use dlapm::predict::predictor::{predict_calls, predict_calls_cached};
use dlapm::store::{StoreKey, WarmStore};

mod common;
use common::TempDir;

#[test]
fn pipeline_generate_save_load_predict_validate() {
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let alg = Potrf { variant: 3, elem: Elem::D };
    let mut store = ModelStore::new(&machine.label());
    let n_gen = coverage::ensure_models(&machine, &mut store, &[&alg], 1352, 536, 42);
    assert!(n_gen >= 3, "expected >= 3 kernel models, got {n_gen}");

    // Round-trip the store through disk.
    let dir = TempDir::new("integration");
    let path = dir.path().join("store.json");
    store.save(&path).unwrap();
    let loaded = ModelStore::load(&path).unwrap();
    assert_eq!(loaded.models.len(), store.models.len());

    // Predict from the loaded store and validate.
    let (n, b) = (1096, 128);
    let pred = predict_calls(&loaded, &alg.calls(n, b));
    assert_eq!(pred.unmodeled_calls, 0);
    let meas = measure_algorithm(&machine, &alg, n, b, 5, 7);
    let re = (pred.time.med - meas.med).abs() / meas.med;
    assert!(re < 0.08, "prediction error {re}");
}

/// ISSUE 5: the full warm-start pipeline at the library level — generate
/// models, predict through a cache, persist both via the WarmStore,
/// reload, and verify the warm state serves bit-identical predictions
/// with zero regeneration and zero recomputation.
#[test]
fn warm_store_roundtrips_models_and_estimate_cache() {
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let alg = Potrf { variant: 3, elem: Elem::D };
    let mut store = ModelStore::new(&machine.label());
    let n_gen = coverage::ensure_models(&machine, &mut store, &[&alg], 536, 104, 5);
    assert!(n_gen > 0);
    let cache = ModelCache::new();
    let calls = alg.calls(520, 104);
    let cold = predict_calls_cached(&store, &calls, &cache);
    assert!(cache.misses() > 0);

    let dir = TempDir::new("warmstore");
    let warm = WarmStore::open(dir.path()).unwrap();
    let models_key = StoreKey {
        machine: machine.label(),
        granularity: 1,
        seed: 5,
        scope: "models_n536_b104".into(),
    };
    warm.save("models_n536_b104", &models_key, &store).unwrap();
    let cache_key = StoreKey { scope: "model_cache_n536_b104".into(), ..models_key.clone() };
    warm.save("model_cache_n536_b104", &cache_key, &cache).unwrap();

    // Reload into a "new process": models identical, nothing regenerates.
    let mut store2: ModelStore =
        warm.load("models_n536_b104", &models_key).unwrap().expect("warm models");
    assert_eq!(store2.models.len(), store.models.len());
    for (case, model) in &store.models {
        assert_eq!(store2.get(case).expect(case), model, "model '{case}' must round-trip");
    }
    let regenerated = coverage::ensure_models(&machine, &mut store2, &[&alg], 536, 104, 5);
    assert_eq!(regenerated, 0, "warm models must satisfy coverage");

    // Warm cache serves every estimate: zero misses, bit-equal totals.
    let cache2: ModelCache =
        warm.load("model_cache_n536_b104", &cache_key).unwrap().expect("warm cache");
    let warm_pred = predict_calls_cached(&store2, &calls, &cache2);
    assert_eq!(warm_pred.time.med.to_bits(), cold.time.med.to_bits());
    assert_eq!(warm_pred.time.std.to_bits(), cold.time.std.to_bits());
    assert_eq!(cache2.misses(), 0, "warm cache must not recompute");
    assert!(cache2.hits() > 0);
}

/// The acceptance criterion of ISSUE 2: a 1-job and an N-job `gen` run
/// produce byte-identical serialized model stores, and cached prediction
/// over the generated store is bit-identical to uncached.
#[test]
fn jobs_parity_one_vs_many_threads_byte_identical() {
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let alg = Potrf { variant: 3, elem: Elem::D };

    let mut store1 = ModelStore::new(&machine.label());
    let e1 = Arc::new(Engine::new(1));
    let n1 = coverage::ensure_models_with(&e1, &machine, &mut store1, &[&alg], 536, 104, 42)
        .unwrap();

    let mut store4 = ModelStore::new(&machine.label());
    let e4 = Arc::new(Engine::new(4));
    let n4 = coverage::ensure_models_with(&e4, &machine, &mut store4, &[&alg], 536, 104, 42)
        .unwrap();

    assert_eq!(n1, n4);
    assert!(n1 >= 3, "expected >= 3 kernel models, got {n1}");
    assert_eq!(
        store1.to_json().render(),
        store4.to_json().render(),
        "1-job and 4-job generation must serialize byte-identically"
    );

    // Cached prediction over the parallel-generated store matches the
    // plain path exactly (default exact-granularity cache).
    let calls = alg.calls(520, 104);
    let plain = predict_calls(&store4, &calls);
    let cache = ModelCache::new();
    let cached = predict_calls_cached(&store4, &calls, &cache);
    assert_eq!(plain.time, cached.time);
    assert!(cache.hits() + cache.misses() > 0);
}

/// ISSUE 3: both scenarios — model-based blocked algorithms and
/// micro-benchmark-based tensor contractions — rank through the one
/// selection core, on the same engine, with validation paired by index.
#[test]
fn unified_selection_core_serves_both_scenarios() {
    use dlapm::select::{
        rank_candidates_par, selection_quality, winner_within, BlockedCandidate, Candidate,
        TensorCandidate, ValidateCfg,
    };
    let engine = Arc::new(Engine::new(3));

    // --- Blocked scenario (Ch. 4): Cholesky variants via models.
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let algs = Potrf::all(Elem::D);
    let mut store = ModelStore::new(&machine.label());
    let refs: Vec<&dyn BlockedAlg> = algs.iter().map(|a| a as _).collect();
    coverage::ensure_models_with(&engine, &machine, &mut store, &refs, 536, 104, 42).unwrap();
    let store = Arc::new(store);
    let cache = Arc::new(ModelCache::new());
    let blocked: Vec<Arc<dyn Candidate + Send + Sync>> = algs
        .iter()
        .map(|a| {
            Arc::new(BlockedCandidate {
                store: Arc::clone(&store),
                cache: Arc::clone(&cache),
                alg: Arc::new(a.clone()),
                n: 520,
                b: 104,
                label: None,
                validate: Some(ValidateCfg {
                    machine: machine.clone(),
                    reps: 3,
                    seed: 7,
                    engine: Arc::clone(&engine),
                }),
            }) as _
        })
        .collect();
    let ranked = rank_candidates_par(&engine, &blocked).unwrap();
    assert_eq!(ranked.len(), algs.len());
    assert!(ranked.iter().all(|r| r.measured.is_some()));
    let q = selection_quality(&ranked).unwrap();
    assert!(q <= 1.10, "blocked selection quality {q}");
    assert!(cache.hits() > 0, "variants must share the estimate cache");

    // --- Tensor scenario (Ch. 6): the same core + engine, micro-based.
    let harper = Machine::standard(CpuId::Harpertown, Library::OpenBlas { fixed_dswap: false }, 1);
    let con = dlapm::tensor::Contraction::example_abc(32);
    let memo = Arc::new(dlapm::tensor::MicroMemo::new());
    let tensor: Vec<Arc<dyn Candidate + Send + Sync>> = dlapm::tensor::generate(&con)
        .into_iter()
        .map(|alg| {
            Arc::new(TensorCandidate {
                machine: harper.clone(),
                con: con.clone(),
                alg,
                elem: Elem::D,
                seed: 11,
                memo: Arc::clone(&memo),
                engine: Arc::clone(&engine),
                validate_reps: 1,
            }) as _
        })
        .collect();
    let ranked = rank_candidates_par(&engine, &tensor).unwrap();
    assert_eq!(ranked.len(), 36);
    assert!(winner_within(&ranked, 0.25).unwrap(), "q={:?}", selection_quality(&ranked));
    assert!(memo.len() < 36, "algorithms must share micro-benchmarks: {}", memo.len());
    // Both rankings render through the one report path.
    let (text, csv) = dlapm::report::selection_table(&ranked);
    assert_eq!(text.lines().count(), 36);
    assert_eq!(csv.lines().count(), 37);
}

#[test]
fn store_save_load_error_paths() {
    let dir = TempDir::new("store_errors");

    // Missing file: load must fail, not panic.
    let missing = dir.path().join("does_not_exist.json");
    let e = ModelStore::load(&missing);
    assert!(e.is_err());

    // Malformed JSON: parse error surfaces as Err.
    let bad = dir.path().join("bad.json");
    std::fs::write(&bad, "{ not json at all").unwrap();
    assert!(ModelStore::load(&bad).is_err());

    // Valid JSON but wrong shape: missing required keys.
    let wrong = dir.path().join("wrong.json");
    std::fs::write(&wrong, r#"{"machine": "x"}"#).unwrap();
    let err = ModelStore::load(&wrong).unwrap_err();
    assert!(err.to_string().contains("models"), "{err}");

    // Wrong-typed values must surface as Err, not panic.
    let typed = dir.path().join("typed.json");
    std::fs::write(&typed, r#"{"machine": "x", "models": 5}"#).unwrap();
    let err = ModelStore::load(&typed).unwrap_err();
    assert!(err.to_string().contains("array"), "{err}");

    // A model piece with lo > hi must surface as Err, not panic.
    let dom = dir.path().join("domain.json");
    std::fs::write(
        &dom,
        r#"{"machine": "x", "models": [{"case": "c", "exps": [[0]], "scale": [1],
            "gen_cost": 0,
            "pieces": [{"lo": [100], "hi": [8],
                        "coeffs": [[1],[1],[1],[1],[0]]}]}]}"#,
    )
    .unwrap();
    let err = ModelStore::load(&dom).unwrap_err();
    assert!(err.to_string().contains("domain"), "{err}");

    // Round trip through a nested path (save creates parent dirs).
    let nested = dir.path().join("a/b/store.json");
    let store = ModelStore::new("testbed/label/1t");
    store.save(&nested).unwrap();
    let loaded = ModelStore::load(&nested).unwrap();
    assert_eq!(loaded.machine_label, "testbed/label/1t");
    assert!(loaded.models.is_empty());
}

#[test]
fn pjrt_polyeval_matches_store_models() {
    let Ok(mut rt) = dlapm::runtime::Runtime::load_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let alg = Potrf { variant: 3, elem: Elem::D };
    let mut store = ModelStore::new(&machine.label());
    coverage::ensure_models(&machine, &mut store, &[&alg], 1352, 536, 42);
    for model in store.models.values() {
        if model.pieces.len() > 64 {
            continue; // exceeds one dispatch; covered by chunked path
        }
        let hull = model.domain_hull();
        let pts: Vec<Vec<usize>> = (0..9)
            .map(|i| hull.lo.iter().zip(&hull.hi).map(|(&l, &h)| l + (h - l) * i / 8).collect())
            .collect();
        let vals = dlapm::runtime::polyeval_model(&mut rt, model, dlapm::util::stats::Stat::Med, &pts).unwrap();
        for (p, v) in pts.iter().zip(&vals) {
            let want = model.estimate(p).med;
            assert!(((v - want) / want).abs() < 1e-9, "{}: {p:?} {v} vs {want}", model.case);
        }
    }
}

#[test]
fn sampler_script_drives_virtual_testbed() {
    let machine = Machine::standard(CpuId::Haswell, Library::Mkl, 1);
    let mut sampler = dlapm::sampler::Sampler::new(machine.session(1));
    let out = sampler
        .run_script("dmalloc A 4000000\ndpotf2 L 512 A 2000\ndpotf2 L 512 A 2000\ngo")
        .unwrap();
    assert_eq!(out.len(), 2);
    let c0: f64 = out[0].parse().unwrap();
    let c1: f64 = out[1].parse().unwrap();
    assert!(c0 > c1, "first call pays init + cold misses: {c0} vs {c1}");
}
