//! Cross-module integration tests: the full generate -> store -> load ->
//! predict -> validate pipeline, plus the PJRT artifact path.

use dlapm::machine::{CpuId, Elem, Library, Machine};
use dlapm::modeling::ModelStore;
use dlapm::predict::algorithms::potrf::Potrf;
use dlapm::predict::algorithms::BlockedAlg;
use dlapm::predict::measurement::{coverage, measure_algorithm};
use dlapm::predict::predictor::predict_calls;

#[test]
fn pipeline_generate_save_load_predict_validate() {
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let alg = Potrf { variant: 3, elem: Elem::D };
    let mut store = ModelStore::new(&machine.label());
    let n_gen = coverage::ensure_models(&machine, &mut store, &[&alg], 1352, 536, 42);
    assert!(n_gen >= 3, "expected >= 3 kernel models, got {n_gen}");

    // Round-trip the store through disk.
    let dir = std::env::temp_dir().join("dlapm_integration");
    let path = dir.join("store.json");
    store.save(&path).unwrap();
    let loaded = ModelStore::load(&path).unwrap();
    assert_eq!(loaded.models.len(), store.models.len());

    // Predict from the loaded store and validate.
    let (n, b) = (1096, 128);
    let pred = predict_calls(&loaded, &alg.calls(n, b));
    assert_eq!(pred.unmodeled_calls, 0);
    let meas = measure_algorithm(&machine, &alg, n, b, 5, 7);
    let re = (pred.time.med - meas.med).abs() / meas.med;
    assert!(re < 0.08, "prediction error {re}");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn pjrt_polyeval_matches_store_models() {
    let Ok(mut rt) = dlapm::runtime::Runtime::load_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let alg = Potrf { variant: 3, elem: Elem::D };
    let mut store = ModelStore::new(&machine.label());
    coverage::ensure_models(&machine, &mut store, &[&alg], 1352, 536, 42);
    for model in store.models.values() {
        if model.pieces.len() > 64 {
            continue; // exceeds one dispatch; covered by chunked path
        }
        let hull = model.domain_hull();
        let pts: Vec<Vec<usize>> = (0..9)
            .map(|i| hull.lo.iter().zip(&hull.hi).map(|(&l, &h)| l + (h - l) * i / 8).collect())
            .collect();
        let vals = dlapm::runtime::polyeval_model(&mut rt, model, dlapm::util::stats::Stat::Med, &pts).unwrap();
        for (p, v) in pts.iter().zip(&vals) {
            let want = model.estimate(p).med;
            assert!(((v - want) / want).abs() < 1e-9, "{}: {p:?} {v} vs {want}", model.case);
        }
    }
}

#[test]
fn sampler_script_drives_virtual_testbed() {
    let machine = Machine::standard(CpuId::Haswell, Library::Mkl, 1);
    let mut sampler = dlapm::sampler::Sampler::new(machine.session(1));
    let out = sampler
        .run_script("dmalloc A 4000000\ndpotf2 L 512 A 2000\ndpotf2 L 512 A 2000\ngo")
        .unwrap();
    assert_eq!(out.len(), 2);
    let c0: f64 = out[0].parse().unwrap();
    let c1: f64 = out[1].parse().unwrap();
    assert!(c0 > c1, "first call pays init + cold misses: {c0} vs {c1}");
}
