//! Bench: tensor-contraction micro-benchmark prediction vs full execution
//! (§6.3.4 efficiency study), plus the unified selection core's scaling
//! axes: cold vs memoized micro-benchmarks and jobs-1 vs jobs-N ranking.
use std::sync::Arc;

use dlapm::engine::Engine;
use dlapm::machine::{CpuId, Elem, Library, Machine};
use dlapm::tensor::exec::execute_full;
use dlapm::tensor::micro::{self, MicroMemo};
use dlapm::tensor::{generate, Contraction};
use dlapm::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::from_env("tensor");
    let machine = Machine::standard(CpuId::Harpertown, Library::OpenBlas { fixed_dswap: false }, 1);
    let con = Contraction::example_abc(48);
    let algs = generate(&con);
    suite.add("generate/abc=ai,ibc", || generate(&con).len());

    let gemm = algs.iter().find(|a| a.name().contains("gemm")).unwrap();
    suite.add("micro_predict/one-alg-cold", || {
        micro::predict(&machine, &con, gemm, Elem::D, 3).seconds
    });
    // Warm memo: after the first call every iteration is a pure lookup.
    let warm = Arc::new(MicroMemo::new());
    micro::predict_with(&machine, &con, gemm, Elem::D, 3, &warm);
    suite.add("micro_predict/one-alg-memoized", || {
        micro::predict_with(&machine, &con, gemm, Elem::D, 3, &warm).seconds
    });
    suite.add("execute_full/one-alg", || execute_full(&machine, &con, gemm, Elem::D, 3));

    suite.add("rank/36-seq-unmemoized", || micro::rank(&machine, &con, &algs, Elem::D, 3).len());
    let e1 = Arc::new(Engine::new(1));
    suite.add("rank/36-jobs1-memoized", || {
        let memo = Arc::new(MicroMemo::new());
        micro::rank_with(&e1, &machine, &con, &algs, Elem::D, 3, &memo).unwrap().len()
    });
    let en = Arc::new(Engine::new(4));
    suite.add("rank/36-jobs4-memoized", || {
        let memo = Arc::new(MicroMemo::new());
        micro::rank_with(&en, &machine, &con, &algs, Elem::D, 3, &memo).unwrap().len()
    });

    // Sweep axis: two nearby sizes, cold (fresh exact memo per size) vs
    // one coarse-granularity memo shared across the sweep — n=30 and
    // n=32 quantize together at g=8, so the second size's benchmarks are
    // pure cross-size memo hits.
    let con30 = Contraction::example_abc(30);
    let con32 = Contraction::example_abc(32);
    let algs30 = generate(&con30);
    let algs32 = generate(&con32);
    suite.add("sweep/30+32-cold", || {
        let m1 = Arc::new(MicroMemo::new());
        let m2 = Arc::new(MicroMemo::new());
        micro::rank_with(&e1, &machine, &con30, &algs30, Elem::D, 3, &m1).unwrap().len()
            + micro::rank_with(&e1, &machine, &con32, &algs32, Elem::D, 3, &m2).unwrap().len()
    });
    suite.add("sweep/30+32-memo-g8", || {
        let memo = Arc::new(MicroMemo::with_granularity(8));
        micro::rank_with(&e1, &machine, &con30, &algs30, Elem::D, 3, &memo).unwrap().len()
            + micro::rank_with(&e1, &machine, &con32, &algs32, Elem::D, 3, &memo).unwrap().len()
    });
    suite.finish();
}
