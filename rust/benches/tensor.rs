//! Bench: tensor-contraction micro-benchmark prediction vs full execution
//! (§6.3.4 efficiency study), plus the unified selection core's scaling
//! axes: cold vs memoized micro-benchmarks and jobs-1 vs jobs-N ranking.
use std::sync::Arc;

use dlapm::engine::Engine;
use dlapm::machine::{CpuId, Elem, Library, Machine};
use dlapm::tensor::exec::execute_full;
use dlapm::tensor::micro::{self, MicroMemo};
use dlapm::tensor::{generate, Contraction};
use dlapm::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::from_env("tensor");
    let machine = Machine::standard(CpuId::Harpertown, Library::OpenBlas { fixed_dswap: false }, 1);
    let con = Contraction::example_abc(48);
    let algs = generate(&con);
    suite.add("generate/abc=ai,ibc", || generate(&con).len());

    let gemm = algs.iter().find(|a| a.name().contains("gemm")).unwrap();
    suite.add("micro_predict/one-alg-cold", || {
        micro::predict(&machine, &con, gemm, Elem::D, 3).seconds
    });
    // Warm memo: after the first call every iteration is a pure lookup.
    let warm = Arc::new(MicroMemo::new());
    micro::predict_with(&machine, &con, gemm, Elem::D, 3, &warm);
    suite.add("micro_predict/one-alg-memoized", || {
        micro::predict_with(&machine, &con, gemm, Elem::D, 3, &warm).seconds
    });
    suite.add("execute_full/one-alg", || execute_full(&machine, &con, gemm, Elem::D, 3));

    suite.add("rank/36-seq-unmemoized", || micro::rank(&machine, &con, &algs, Elem::D, 3).len());
    let e1 = Arc::new(Engine::new(1));
    suite.add("rank/36-jobs1-memoized", || {
        let memo = Arc::new(MicroMemo::new());
        micro::rank_with(&e1, &machine, &con, &algs, Elem::D, 3, &memo).unwrap().len()
    });
    let en = Arc::new(Engine::new(4));
    suite.add("rank/36-jobs4-memoized", || {
        let memo = Arc::new(MicroMemo::new());
        micro::rank_with(&en, &machine, &con, &algs, Elem::D, 3, &memo).unwrap().len()
    });
    suite.finish();
}
