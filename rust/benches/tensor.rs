//! Bench: tensor-contraction micro-benchmark prediction vs full execution
//! (§6.3.4 efficiency study).
use dlapm::machine::{CpuId, Elem, Library, Machine};
use dlapm::tensor::exec::execute_full;
use dlapm::tensor::{generate, micro, Contraction};
use dlapm::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::from_env("tensor");
    let machine = Machine::standard(CpuId::Harpertown, Library::OpenBlas { fixed_dswap: false }, 1);
    let con = Contraction::example_abc(48);
    let algs = generate(&con);
    suite.add("generate/abc=ai,ibc", || generate(&con).len());
    let gemm = algs.iter().find(|a| a.name().contains("gemm")).unwrap();
    suite.add("micro_predict/one-alg", || micro::predict(&machine, &con, gemm, Elem::D, 3).seconds);
    suite.add("execute_full/one-alg", || execute_full(&machine, &con, gemm, Elem::D, 3));
    suite.add("rank/36-algorithms", || micro::rank(&machine, &con, &algs, Elem::D, 3).len());
    suite.finish();
}
