//! Bench: model generation (Table 3.2 "model cost" analogue), the
//! relative-LSQ fit backends (Rust vs PJRT artifact), and the parallel
//! engine's sequential-vs-parallel generation comparison.
use std::sync::Arc;

use dlapm::engine::{available_parallelism, Engine};
use dlapm::machine::{Call, KernelId, Uplo};
use dlapm::machine::{CpuId, Elem, Library, Machine};
use dlapm::modeling::fit::{design_matrix, rust_fit};
use dlapm::modeling::generator::{generate_model, generate_model_with, GenConfig};
use dlapm::modeling::{Domain, ModelStore};
use dlapm::predict::algorithms::potrf::Potrf;
use dlapm::predict::measurement::coverage;
use dlapm::util::bench::BenchSuite;
use dlapm::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::from_env("modeling");
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let mut potf2 = Call::new(KernelId::Potf2, Elem::D);
    potf2.flags.uplo = Some(Uplo::Lower);
    let domain = Domain::new(vec![24], vec![536]);
    suite.add("generate_model/dpotf2-1D", || {
        generate_model(&machine, &GenConfig { reps: 5, ..Default::default() }, &potf2, &domain, 1).1.pieces
    });

    // Split-level parallelism within one 2-D case: sequential vs all-core
    // engine on the same deterministic workload.
    let mut trsm = Call::new(KernelId::Trsm, Elem::D);
    trsm.flags.side = Some(dlapm::machine::Side::Left);
    trsm.flags.uplo = Some(Uplo::Lower);
    trsm.flags.trans_a = Some(dlapm::machine::Trans::No);
    trsm.flags.diag = Some(dlapm::machine::Diag::NonUnit);
    let trsm_domain = Domain::new(vec![24, 24], vec![536, 1048]);
    let gen_cfg = GenConfig { reps: 5, oversampling: 2, ..Default::default() };
    let seq_engine = Engine::sequential();
    let par_engine = Engine::new(available_parallelism());
    suite.add("generate_case/dtrsm-2D-jobs1", || {
        generate_model_with(&seq_engine, &machine, &gen_cfg, &trsm, &trsm_domain, 1)
            .unwrap()
            .1
            .pieces
    });
    suite.add(
        &format!("generate_case/dtrsm-2D-jobs{}", par_engine.jobs()),
        || {
            generate_model_with(&par_engine, &machine, &gen_cfg, &trsm, &trsm_domain, 1)
                .unwrap()
                .1
                .pieces
        },
    );

    // Case-level parallelism: the `gen --all` path over every case the
    // potrf variants need (the multi-case workload of the CLI).
    let algs = Potrf::all(Elem::D);
    let e1 = Arc::new(Engine::new(1));
    let en = Arc::new(Engine::new(available_parallelism()));
    suite.add("gen_all/potrf-jobs1", || {
        let refs: Vec<&dyn dlapm::predict::BlockedAlg> =
            algs.iter().map(|a| a as &dyn dlapm::predict::BlockedAlg).collect();
        let mut store = ModelStore::new("bench");
        coverage::ensure_models_with(&e1, &machine, &mut store, &refs, 536, 104, 1).unwrap()
    });
    suite.add(&format!("gen_all/potrf-jobs{}", en.jobs()), || {
        let refs: Vec<&dyn dlapm::predict::BlockedAlg> =
            algs.iter().map(|a| a as &dyn dlapm::predict::BlockedAlg).collect();
        let mut store = ModelStore::new("bench");
        coverage::ensure_models_with(&en, &machine, &mut store, &refs, 536, 104, 1).unwrap()
    });

    // Engine wake latency: a fully idle pool (workers parked on the
    // condvar) accepts and completes a batch. Before the wake-counter
    // rewrite every idle worker polled on a 20 ms timeout; now a
    // submission burst notifies parked workers exactly once.
    let idle = Engine::new(available_parallelism());
    idle.run(vec![|| 0usize]).unwrap(); // spawn + park once before timing
    suite.add("engine/idle-wake-1job", || idle.run(vec![|| 1usize]).unwrap()[0]);
    suite.add("engine/idle-wake-64fanout", || {
        idle.run((0..64usize).map(|i| move || i).collect::<Vec<_>>()).unwrap().len()
    });

    // Fit backends on a 128x12 system.
    let mut rng = Rng::new(3);
    let exps: Vec<Vec<u8>> = (0..4u8).flat_map(|i| (0..3u8).map(move |j| vec![i, j])).collect();
    let pts: Vec<Vec<f64>> = (0..128).map(|_| vec![rng.range_f64(0.05, 1.0), rng.range_f64(0.05, 1.0)]).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p[0] * p[0] * p[1] + 0.01).collect();
    let x = design_matrix(&pts, &ys, &exps);
    suite.add("fit/rust-128x12", || rust_fit(&x, 128, 12)[0]);
    if let Ok(mut rt) = dlapm::runtime::Runtime::load_default() {
        suite.add("fit/pjrt-128x12", || rt.fit(&x, 128, 12).unwrap()[0]);
    }
    suite.finish();
}
