//! Bench: model generation (Table 3.2 "model cost" analogue) and the
//! relative-LSQ fit backends (Rust vs PJRT artifact).
use dlapm::machine::{CpuId, Elem, Library, Machine};
use dlapm::machine::{Call, KernelId, Uplo};
use dlapm::modeling::fit::{design_matrix, rust_fit};
use dlapm::modeling::generator::{generate_model, GenConfig};
use dlapm::modeling::Domain;
use dlapm::util::bench::BenchSuite;
use dlapm::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::from_env("modeling");
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let mut potf2 = Call::new(KernelId::Potf2, Elem::D);
    potf2.flags.uplo = Some(Uplo::Lower);
    let domain = Domain::new(vec![24], vec![536]);
    suite.add("generate_model/dpotf2-1D", || {
        generate_model(&machine, &GenConfig { reps: 5, ..Default::default() }, &potf2, &domain, 1).1.pieces
    });

    // Fit backends on a 128x12 system.
    let mut rng = Rng::new(3);
    let exps: Vec<Vec<u8>> = (0..4u8).flat_map(|i| (0..3u8).map(move |j| vec![i, j])).collect();
    let pts: Vec<Vec<f64>> = (0..128).map(|_| vec![rng.range_f64(0.05, 1.0), rng.range_f64(0.05, 1.0)]).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p[0] * p[0] * p[1] + 0.01).collect();
    let x = design_matrix(&pts, &ys, &exps);
    suite.add("fit/rust-128x12", || rust_fit(&x, 128, 12)[0]);
    if let Ok(mut rt) = dlapm::runtime::Runtime::load_default() {
        suite.add("fit/pjrt-128x12", || rt.fit(&x, 128, 12).unwrap()[0]);
    }
}
