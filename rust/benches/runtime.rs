//! Bench: PJRT artifact dispatch latencies (L2/L1 layer costs).
use dlapm::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::from_env("runtime");
    let Ok(mut rt) = dlapm::runtime::Runtime::load_default() else {
        eprintln!("artifacts missing; run `make artifacts`");
        return;
    };
    let n = rt.entry("gemm").unwrap().constants["n"];
    let a: Vec<f32> = (0..n * n).map(|i| (i % 7) as f32).collect();
    let b = a.clone();
    suite.add("gemm/pallas-256", || rt.gemm(&a, &b).unwrap().len());

    let coeffs = vec![1.0; 24 * 4];
    let exps: Vec<i32> = (0..24).flat_map(|_| [1, 0, 0]).collect();
    let idx = vec![0i32; 2048];
    let pts = vec![0.5f64; 2048 * 3];
    suite.add_throughput("polyeval/full-batch-2048", 2048, "pts", || {
        rt.polyeval(&coeffs, 4, 24, &idx, &pts, 3, &exps).unwrap().len()
    });
    suite.finish();
}
