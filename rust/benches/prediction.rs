//! Bench: the prediction hot path (paper headline — predictions are
//! orders of magnitude faster than measurement). Covers Fig 4.12/4.14
//! selection sweeps and the scalar vs PJRT polyeval backends.
use dlapm::machine::{CpuId, Elem, Library, Machine};
use dlapm::modeling::ModelStore;
use dlapm::predict::algorithms::potrf::Potrf;
use dlapm::predict::algorithms::BlockedAlg;
use dlapm::predict::measurement::coverage;
use dlapm::predict::predictor::predict_calls;
use dlapm::util::bench::BenchSuite;

fn main() {
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let alg = Potrf { variant: 3, elem: Elem::D };
    let mut store = ModelStore::new(&machine.label());
    coverage::ensure_models(&machine, &mut store, &[&alg], 2056, 536, 42);

    let mut suite = BenchSuite::from_env("prediction");
    let calls = alg.calls(2008, 128);
    suite.add_throughput("predict_calls/potrf-n2008", calls.len() as u64, "calls", || {
        predict_calls(&store, &calls).time.med
    });
    suite.add("call_sequence_gen/potrf-n2008", || alg.calls(2008, 128).len());
    suite.add("blocksize_sweep/65-candidates", || {
        let bs: Vec<usize> = (24..=536).step_by(8).collect();
        dlapm::predict::blocksize::optimize_blocksize(&store, &alg, 2008, &bs).b_pred
    });
    // PJRT vs scalar backend on one model.
    if let Ok(mut rt) = dlapm::runtime::Runtime::load_default() {
        // Pick a model that fits one 64-piece polyeval dispatch.
        let model = store
            .models
            .values()
            .filter(|m| m.pieces.len() <= 64)
            .max_by_key(|m| m.pieces.len())
            .unwrap()
            .clone();
        let pts: Vec<Vec<usize>> = (24..536).step_by(2).map(|v| vec![v.min(536); model.dims()]).collect();
        suite.add_throughput("polyeval/scalar", pts.len() as u64, "pts", || {
            pts.iter().map(|p| model.estimate(p).med).sum::<f64>()
        });
        suite.add_throughput("polyeval/pjrt", pts.len() as u64, "pts", || {
            dlapm::runtime::polyeval_model(&mut rt, &model, dlapm::util::stats::Stat::Med, &pts).unwrap().len()
        });
    }
}
