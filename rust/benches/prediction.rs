//! Bench: the prediction hot path (paper headline — predictions are
//! orders of magnitude faster than measurement). Covers Fig 4.12/4.14
//! selection sweeps, cold-vs-warm estimate-cache prediction, batched
//! model evaluation, block-size sweeps through the selection core
//! (batched prewarm vs a per-b loop), the serve daemon's request path
//! (cold vs resident-warm, plus contended coalescing), and the scalar
//! vs PJRT polyeval backends.
use std::sync::Arc;

use dlapm::engine::{Engine, ModelCache};
use dlapm::machine::{CpuId, Elem, Library, Machine};
use dlapm::modeling::ModelStore;
use dlapm::predict::algorithms::potrf::Potrf;
use dlapm::predict::algorithms::BlockedAlg;
use dlapm::predict::measurement::coverage;
use dlapm::predict::predictor::{predict_calls, predict_calls_cached};
use dlapm::serve::{Coalescer, ServeOpts, ServeState};
use dlapm::util::bench::BenchSuite;
use dlapm::util::stats::Summary;

fn main() {
    let machine = Machine::standard(CpuId::SandyBridge, Library::OpenBlas { fixed_dswap: false }, 1);
    let alg = Potrf { variant: 3, elem: Elem::D };
    let mut store = ModelStore::new(&machine.label());
    coverage::ensure_models(&machine, &mut store, &[&alg], 2056, 536, 42);

    let mut suite = BenchSuite::from_env("prediction");
    let calls = alg.calls(2008, 128);
    suite.add_throughput("predict_calls/potrf-n2008", calls.len() as u64, "calls", || {
        predict_calls(&store, &calls).time.med
    });
    // Cold cache: a fresh ModelCache per iteration (every call misses).
    suite.add_throughput("predict_cached/cold", calls.len() as u64, "calls", || {
        let cache = ModelCache::new();
        predict_calls_cached(&store, &calls, &cache).time.med
    });
    // Warm cache: one shared cache across iterations (every call hits
    // after the first pass — the memoized batched-prediction regime).
    let warm = ModelCache::new();
    predict_calls_cached(&store, &calls, &warm);
    suite.add_throughput("predict_cached/warm", calls.len() as u64, "calls", || {
        predict_calls_cached(&store, &calls, &warm).time.med
    });
    suite.add("call_sequence_gen/potrf-n2008", || alg.calls(2008, 128).len());
    // Block-size sweep, unbatched reference: one predict_calls per b —
    // every call pays its own piece lookup and polynomial evaluation.
    let bs: Vec<usize> = dlapm::predict::blocksize::standard_bs();
    suite.add("blocksize_sweep/65-unbatched-loop", || {
        bs.iter()
            .map(|&b| predict_calls(&store, &alg.calls(2008, b)).time.med)
            .fold(f64::INFINITY, f64::min)
    });
    // The selection-core path: ordered evaluate_batch prewarm + cached
    // candidates ranked via rank_candidates_par (bit-identical results).
    let store_arc = Arc::new(store.clone());
    let alg_arc: Arc<dyn BlockedAlg + Send + Sync> = Arc::new(alg);
    let seq = Arc::new(Engine::sequential());
    suite.add("blocksize_sweep/65-batched-core", || {
        let cache = Arc::new(ModelCache::new());
        dlapm::predict::blocksize::optimize_blocksize_with(&seq, &store_arc, &cache, &alg_arc, 2008, &bs)
            .unwrap()
            .0
            .b_pred
    });
    // Warm shared cache across sweep repetitions: the cross-sweep regime
    // of repeated `figures` runs (every candidate prediction hits).
    let warm_cache = Arc::new(ModelCache::new());
    dlapm::predict::blocksize::optimize_blocksize_with(&seq, &store_arc, &warm_cache, &alg_arc, 2008, &bs)
        .unwrap();
    suite.add("blocksize_sweep/65-batched-warm", || {
        dlapm::predict::blocksize::optimize_blocksize_with(&seq, &store_arc, &warm_cache, &alg_arc, 2008, &bs)
            .unwrap()
            .0
            .b_pred
    });
    // Prediction-as-a-service: the daemon's request path on a small
    // contraction ranking. Cold pays state construction plus the first
    // micro-benchmark pass; warm is the resident-daemon steady state
    // (every memo lookup hits, the response is recomputed pure).
    let req = r#"{"op":"contract_rank","spec":"abc=ai,ibc","n":16,"small":4,"seed":7}"#;
    let opts = |batch_window: u64| ServeOpts {
        store_dir: None,
        jobs: 1,
        checkpoint_every: 0,
        max_connections: 0,
        max_queue: 0,
        batch_window,
        batch_max: 0,
    };
    suite.add("serve/handle-contract-cold", || {
        let state = ServeState::new(&opts(0)).unwrap();
        state.handle_line(req).unwrap().len()
    });
    let resident = ServeState::new(&opts(0)).unwrap();
    resident.handle_line(req).unwrap();
    suite.add("serve/handle-contract-warm", || resident.handle_line(req).unwrap().len());
    // Admission batching A/B: four same-scope selects at mixed sizes,
    // answered per request (window 0: one warm pass, one prewarm sweep
    // and one engine fan-out EACH) vs fused (window 8: the whole class
    // shares one of each). Responses are byte-identical; only the
    // execution shape differs. Warm states: the steady-state regime.
    let mixed_selects = concat!(
        r#"{"op":"select","cpu":"sandybridge","n":480,"b":104,"seed":5,"id":1}"#,
        "\n",
        r#"{"op":"select","cpu":"sandybridge","n":400,"b":104,"seed":5,"id":2}"#,
        "\n",
        r#"{"op":"select","cpu":"sandybridge","n":360,"b":104,"seed":5,"id":3}"#,
        "\n",
        r#"{"op":"select","cpu":"sandybridge","n":440,"b":104,"seed":5,"id":4}"#,
        "\n",
    );
    let unbatched = ServeState::new(&opts(0)).unwrap();
    unbatched.handle_script(mixed_selects);
    suite.add("serve/unbatched-mixed-sizes", || {
        unbatched.handle_script(mixed_selects).len()
    });
    let batched = ServeState::new(&opts(8)).unwrap();
    batched.handle_script(mixed_selects);
    suite.add("serve/batched-mixed-sizes", || batched.handle_script(mixed_selects).len());
    // Contended coalescing: 8 threads race one key — one leads, the rest
    // park on the condvar and clone the leader's value.
    suite.add("serve/coalesce-contended", || {
        let co: Coalescer<u64> = Coalescer::new("bench-coalesce");
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..8 {
                handles.push(s.spawn(|| co.run("k", || 1u64)));
            }
            let mut total = 0u64;
            for h in handles {
                total += h.join().unwrap();
            }
            total
        })
    });
    // Sharded variant: 8 threads race 8 *distinct* keys. With one shard
    // (the PR-7 layout) they all serialize on the table mutex; across 8
    // shards each key parks and sweeps on its own lock.
    suite.add("serve/coalesce-contended-sharded", || {
        let co: Coalescer<u64> = Coalescer::with_shards("bench-coalesce-sharded", 8);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..8u64 {
                let co = &co;
                handles.push(s.spawn(move || co.run(&format!("k{t}"), || t)));
            }
            let mut total = 0u64;
            for h in handles {
                total += h.join().unwrap();
            }
            total
        })
    });
    // Cache contention A/B: 4 threads hammer one fully warm ModelCache
    // (pure hit path) — the single global lock every PR-7 lookup took vs
    // the sharded default. Identical work, identical results; only the
    // lock layout differs.
    let hot_cache = |shards: usize| {
        let cache = ModelCache::with_shards(1, shards);
        for i in 0..64usize {
            let n = (i + 1) * 8;
            cache.preload("dpotf2_L_a1", &[n], Summary::constant(n as f64));
        }
        cache
    };
    let hammer = |cache: &ModelCache| -> f64 {
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..4usize {
                handles.push(s.spawn(move || {
                    let mut acc = 0.0;
                    for i in 0..2000usize {
                        let n = ((i * 7 + t * 13) % 64 + 1) * 8;
                        acc += cache
                            .get_or_insert_with("dpotf2_L_a1", &[n], |sz| {
                                Summary::constant(sz[0] as f64)
                            })
                            .med;
                    }
                    acc
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        })
    };
    let shared_cache = hot_cache(1);
    suite.add("cache/jobs4-hot-shared", || hammer(&shared_cache));
    let sharded_cache = hot_cache(16);
    suite.add("cache/jobs4-hot-sharded", || hammer(&sharded_cache));
    // Batched evaluation: ordered sweep through one model's domain.
    if let Some(model) = store.models.values().max_by_key(|m| m.pieces.len()) {
        let pts: Vec<Vec<usize>> =
            (24..2048).step_by(2).map(|v| vec![v; model.dims()]).collect();
        suite.add_throughput("evaluate/per-point", pts.len() as u64, "pts", || {
            pts.iter().map(|p| model.estimate(p).med).sum::<f64>()
        });
        suite.add_throughput("evaluate/batch", pts.len() as u64, "pts", || {
            model.evaluate_batch(&pts).iter().map(|s| s.med).sum::<f64>()
        });
    }
    // PJRT vs scalar backend on one model.
    if let Ok(mut rt) = dlapm::runtime::Runtime::load_default() {
        // Pick a model that fits one 64-piece polyeval dispatch.
        let model = store
            .models
            .values()
            .filter(|m| m.pieces.len() <= 64)
            .max_by_key(|m| m.pieces.len())
            .unwrap()
            .clone();
        let pts: Vec<Vec<usize>> = (24..536).step_by(2).map(|v| vec![v.min(536); model.dims()]).collect();
        suite.add_throughput("polyeval/scalar", pts.len() as u64, "pts", || {
            pts.iter().map(|p| model.estimate(p).med).sum::<f64>()
        });
        suite.add_throughput("polyeval/pjrt", pts.len() as u64, "pts", || {
            dlapm::runtime::polyeval_model(&mut rt, &model, dlapm::util::stats::Stat::Med, &pts).unwrap().len()
        });
    }
    // Metrics hot path: the per-event cost every migrated mirror pays on
    // the production path — 10k sharded-counter increments plus a
    // cross-shard read, on one cache-line-aligned obs counter.
    suite.add("engine/metrics-hot-path", || {
        let h = dlapm::obs::metrics::handles();
        for _ in 0..10_000u32 {
            h.engine_jobs.add(1);
        }
        h.engine_jobs.get()
    });
    // Observability overhead A/B: the same warm fused-select script with
    // span tracing off (global default) vs streaming JSON-lines to a
    // file. Responses are byte-identical either way; the delta is the
    // pure cost of span assembly and buffered trace writes. These two
    // run LAST because trace::init is one-way and process-global — the
    // "off" leg must be measured before the sink exists.
    let traced = ServeState::new(&opts(8)).unwrap();
    traced.handle_script(mixed_selects);
    suite.add("serve/traced-vs-untraced/off", || traced.handle_script(mixed_selects).len());
    let trace_path =
        std::env::temp_dir().join(format!("dlapm_bench_trace_{}.jsonl", std::process::id()));
    dlapm::obs::trace::init(trace_path.to_str().unwrap()).unwrap();
    suite.add("serve/traced-vs-untraced/on", || traced.handle_script(mixed_selects).len());
    let _ = std::fs::remove_file(&trace_path);
    suite.finish();
}
